//! The RTL-equivalent accelerator model: functional behaviour (bit-exact
//! golden-model arithmetic) + cycle timing + cost/energy accounting, all
//! per the Fig. 2 organization.
//!
//! Functionally a batch is computed exactly as the silicon would: each of
//! the `p` block-FAUs independently accumulates its partial `(m, ell, o)`
//! triplet over its KV sub-block, the ACC cascade merges them (Eq. 1 in
//! float for FA-2, Eq. 16 in the log domain for H-FA), and the final
//! DIV/LogDiv normalizes.

use crate::sync::Arc;

use crate::attention::prepared::{kv_block_ranges, PreparedKv};
use crate::attention::{fa2, merge};
use crate::config::AcceleratorConfig;
use crate::hw::cost::datapath::{accelerator as datapath_inventory, Arith};
use crate::hw::cost::sram::SramConfig;
use crate::hw::cost::scaling::Node;
use crate::hw::pipeline::{simulate, CycleStats, LatencyModel};
use crate::Mat;

/// A configured accelerator instance holding preloaded KV buffers (the
/// prepared form: fixed-size chunks of K row-major plus V resident in
/// log-domain lanes — one chunk per block-FAU-sized SRAM buffer).
pub struct Accelerator {
    pub arith: Arith,
    pub cfg: AcceleratorConfig,
    pub lat: LatencyModel,
    kv: Option<Arc<PreparedKv>>,
}

impl Accelerator {
    pub fn new(arith: Arith, cfg: AcceleratorConfig) -> Accelerator {
        let lat = LatencyModel::for_head_dim(cfg.head_dim);
        Accelerator { arith, cfg, lat, kv: None }
    }

    /// Load the K/V matrices into the (modelled) SRAM buffers, paying the
    /// BF16 rounding and the one-time V->LNS preparation here.
    pub fn load_kv(&mut self, k: Mat, v: Mat) -> anyhow::Result<()> {
        self.check_shape(k.rows, k.cols, v.rows, v.cols)?;
        self.kv = Some(Arc::new(PreparedKv::new(k.round_bf16(), v.round_bf16())));
        Ok(())
    }

    /// Adopt an already-prepared KV set (e.g. from the coordinator's
    /// session store) without copying or reconverting anything.  The
    /// caller owns the BF16 ingress convention.
    pub fn load_prepared(&mut self, kv: Arc<PreparedKv>) -> anyhow::Result<()> {
        self.check_shape(kv.n(), kv.d(), kv.n(), kv.dv())?;
        self.kv = Some(kv);
        Ok(())
    }

    /// Append decode-step rows to the loaded KV (models the DMA of the
    /// new tokens into the resident SRAM tail): the new rows are
    /// BF16-rounded and linear->log converted; resident rows are
    /// untouched.  If the prepared set is shared (e.g. adopted from the
    /// coordinator store) it is copied on write; a uniquely-held set
    /// grows in place.
    pub fn append_kv(&mut self, k_rows: &Mat, v_rows: &Mat) -> anyhow::Result<()> {
        let kv = self.kv.as_mut().ok_or_else(|| anyhow::anyhow!("KV not loaded"))?;
        anyhow::ensure!(
            k_rows.cols == self.cfg.head_dim && v_rows.cols == self.cfg.head_dim,
            "append dim mismatch"
        );
        anyhow::ensure!(k_rows.rows == v_rows.rows, "K/V append row count mismatch");
        anyhow::ensure!(
            kv.n() + k_rows.rows <= self.cfg.seq_len,
            "append overflows KV SRAM capacity: {} + {} > {}",
            kv.n(),
            k_rows.rows,
            self.cfg.seq_len
        );
        Arc::make_mut(kv).append(&k_rows.round_bf16(), &v_rows.round_bf16());
        Ok(())
    }

    /// `seq_len` is the SRAM *capacity*: any resident length `1..=seq_len`
    /// is valid (decode sessions grow toward it via [`Accelerator::append_kv`]).
    fn check_shape(&self, kr: usize, kc: usize, vr: usize, vc: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=self.cfg.seq_len).contains(&kr) && kc == self.cfg.head_dim,
            "K shape {}x{} incompatible with SRAM capacity {}x{}",
            kr,
            kc,
            self.cfg.seq_len,
            self.cfg.head_dim
        );
        anyhow::ensure!(vr == kr && vc == kc, "V shape mismatch");
        Ok(())
    }

    pub fn kv_loaded(&self) -> bool {
        self.kv.is_some()
    }

    /// Compute attention for a batch of queries against the loaded
    /// session, returning outputs and the cycle-level timing of the run.
    /// The single-session case of [`Accelerator::compute_plan`] — same
    /// arithmetic, same formulas.
    pub fn compute_batch(&self, q: &Mat) -> anyhow::Result<(Mat, CycleStats)> {
        let kv = self.kv.clone().ok_or_else(|| anyhow::anyhow!("KV not loaded"))?;
        let (mut outs, stats) = self.compute_plan(&[(&kv, q)])?;
        Ok((outs.pop().expect("one plan entry in, one output out"), stats))
    }

    /// Fused cross-session dispatch: one `(prepared KV, queries)` pair
    /// per session.  Functionally, the H-FA arm schedules **all**
    /// sessions' `(query-tile x KV-block)` grid cells through one pool
    /// pass ([`crate::attention::prepared::attention_multi`]) with
    /// per-query merges in block order, so each session's output is
    /// bit-identical to computing it alone.  Timing models the
    /// super-batch as **per-session sub-launches**: each session pays
    /// the full `simulate` formula over its own resident length and
    /// query count (the silicon's KV SRAM holds one session at a time,
    /// so sub-launches serialize) and the stats are summed — identical
    /// to `compute_batch` when the plan has one session.
    pub fn compute_plan(
        &self,
        plan: &[(&Arc<PreparedKv>, &Mat)],
    ) -> anyhow::Result<(Vec<Mat>, CycleStats)> {
        anyhow::ensure!(!plan.is_empty(), "empty compute plan");
        for (kv, q) in plan {
            self.check_shape(kv.n(), kv.d(), kv.n(), kv.dv())?;
            anyhow::ensure!(q.cols == self.cfg.head_dim, "query dim mismatch");
        }
        let qs: Vec<Mat> = plan.iter().map(|(_, q)| q.round_bf16()).collect();

        let p = self.cfg.kv_blocks;
        let outs = match self.arith {
            Arith::Fa2 => {
                // p block-FAUs -> ACC cascade (Eq. 1) -> DIV, session by
                // session; each block's K/V is materialized from the
                // chunk table (the same per-block copy the dense layout
                // paid via `rows_slice`) — block boundaries are
                // count-driven and unchanged, so the merge cascade is
                // identical
                plan.iter()
                    .zip(&qs)
                    .map(|(&(kv, _), q)| {
                        let mut acc: Option<Vec<fa2::Fa2State>> = None;
                        for (lo, hi) in kv_block_ranges(kv.n(), p) {
                            let kb = kv.k_rows(lo, hi);
                            let vb = kv.v_rows(lo, hi);
                            let st = fa2::partial_states(q, &kb, &vb, None, None);
                            acc = Some(match acc {
                                None => st,
                                Some(prev) => prev
                                    .iter()
                                    .zip(&st)
                                    .map(|(a, b)| merge::merge_fa2(a, b))
                                    .collect(),
                            });
                        }
                        let states = acc.unwrap();
                        let mut out = Mat::zeros(q.rows, self.cfg.head_dim);
                        for (i, st) in states.iter().enumerate() {
                            // DIV output rounds to BF16 on the way out
                            for (j, x) in st.finalize().iter().enumerate() {
                                out.set(i, j, crate::Bf16::from_f32(*x).to_f32());
                            }
                        }
                        out
                    })
                    .collect()
            }
            // prepared path: resident LNS lanes resolved through each
            // session's chunk table, all sessions' (query-tile x
            // block-FAU) cells fanned out as one ragged grid and merged
            // in block order (Eq. 16) — Fig. 2's two parallel axes plus
            // the cross-session axis.  Bit-identical to the sequential
            // golden blocked model per session (tests below and
            // rust/tests/hw_equivalence.rs).
            Arith::Hfa => {
                let fused: Vec<(&PreparedKv, &Mat)> =
                    plan.iter().zip(&qs).map(|(&(kv, _), q)| (kv.as_ref(), q)).collect();
                crate::attention::prepared::attention_multi(
                    &fused,
                    p,
                    None,
                    crate::attention::kernel::DEFAULT_QUERY_TILE,
                )
            }
        };

        // timing follows each session's *resident* length (== seq_len
        // when full; shorter mid-decode), not the SRAM capacity.  The
        // host-side grid schedule above does not enter the model:
        // `simulate` prices the silicon's fixed p block-FAUs x
        // parallel_queries datapath per sub-launch, which is unchanged
        // by how the emulation spreads the same arithmetic over worker
        // threads; sub-launches accumulate because the modelled SRAM
        // swap serializes sessions.
        let mut stats: Option<CycleStats> = None;
        for (&(kv, _), q) in plan.iter().zip(&qs) {
            let s = simulate(
                self.cfg.head_dim,
                kv.n(),
                p,
                self.cfg.parallel_queries,
                q.rows,
                self.lat,
            );
            stats = Some(match stats {
                None => s,
                Some(acc) => accumulate_launches(acc, s),
            });
        }
        Ok((outs, stats.expect("non-empty plan")))
    }

    /// Datapath inventory of this instance.
    pub fn inventory(&self) -> crate::hw::cost::components::Inventory {
        datapath_inventory(
            self.arith,
            self.cfg.head_dim,
            self.cfg.kv_blocks,
            self.cfg.parallel_queries,
        )
    }

    /// KV SRAM subsystem of this instance (28 nm).
    pub fn sram(&self) -> SramConfig {
        SramConfig::kv_buffers(self.cfg.seq_len, self.cfg.head_dim, self.cfg.kv_blocks, Node::N28)
    }
}

/// Combine two sequential sub-launches' timings: elapsed quantities
/// (cycles, rounds, busy unit-cycles, SRAM reads) add; instantaneous
/// quantities (unit counts — the same silicon runs every sub-launch)
/// stay, and `keys_per_fau` reports the longest stream of any launch.
fn accumulate_launches(a: CycleStats, b: CycleStats) -> CycleStats {
    CycleStats {
        cycles: a.cycles + b.cycles,
        rounds: a.rounds + b.rounds,
        keys_per_fau: a.keys_per_fau.max(b.keys_per_fau),
        fau_busy: a.fau_busy + b.fau_busy,
        acc_busy: a.acc_busy + b.acc_busy,
        div_busy: a.div_busy + b.div_busy,
        fau_units: a.fau_units.max(b.fau_units),
        acc_units: a.acc_units.max(b.acc_units),
        div_units: a.div_units.max(b.div_units),
        sram_word_reads: a.sram_word_reads + b.sram_word_reads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact, hfa, Impl};
    use crate::proptest::Rng;

    fn accel(arith: Arith, d: usize, n: usize, p: usize) -> (Accelerator, Mat, Mat) {
        let mut rng = Rng::new(77);
        let cfg = AcceleratorConfig {
            head_dim: d,
            seq_len: n,
            kv_blocks: p,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        let k = Mat::from_vec(n, d, rng.normal_vec(n * d));
        let v = Mat::from_vec(n, d, rng.normal_vec(n * d));
        let mut a = Accelerator::new(arith, cfg);
        a.load_kv(k.clone(), v.clone()).unwrap();
        (a, k.round_bf16(), v.round_bf16())
    }

    #[test]
    fn fa2_accelerator_matches_reference_attention() {
        let (a, k, v) = accel(Arith::Fa2, 32, 256, 4);
        let mut rng = Rng::new(5);
        let q = Mat::from_vec(4, 32, rng.normal_vec(4 * 32)).round_bf16();
        let (out, stats) = a.compute_batch(&q).unwrap();
        let reference = exact::attention(&q, &k, &v, None, None);
        let rel = out.rel_rms(&reference);
        assert!(rel < 0.02, "fa2 accel rel {rel}");
        assert!(stats.cycles > 0);
    }

    #[test]
    fn hfa_accelerator_matches_blocked_golden_model() {
        let (a, k, v) = accel(Arith::Hfa, 16, 128, 4);
        let mut rng = Rng::new(6);
        let q = Mat::from_vec(3, 16, rng.normal_vec(3 * 16)).round_bf16();
        let (out, _) = a.compute_batch(&q).unwrap();
        let golden = hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(out.data, golden.data, "accelerator must be bit-exact vs golden");
    }

    #[test]
    fn both_designs_report_identical_latency() {
        // Section VI-C: same computation order, same pipelined latency
        let (fa2a, _, _) = accel(Arith::Fa2, 64, 512, 4);
        let (hfaa, _, _) = accel(Arith::Hfa, 64, 512, 4);
        let mut rng = Rng::new(9);
        let q = Mat::from_vec(2, 64, rng.normal_vec(2 * 64));
        let (_, s1) = fa2a.compute_batch(&q).unwrap();
        let (_, s2) = hfaa.compute_batch(&q).unwrap();
        assert_eq!(s1.cycles, s2.cycles);
    }

    #[test]
    fn rejects_mismatched_shapes() {
        let (mut a, _, _) = accel(Arith::Hfa, 32, 256, 4);
        assert!(a.load_kv(Mat::zeros(300, 32), Mat::zeros(300, 32)).is_err(), "over capacity");
        assert!(a.load_kv(Mat::zeros(100, 16), Mat::zeros(100, 16)).is_err(), "wrong head dim");
        assert!(a.load_kv(Mat::zeros(0, 32), Mat::zeros(0, 32)).is_err(), "empty KV");
        let q = Mat::zeros(1, 16);
        assert!(a.compute_batch(&q).is_err());
        // partial residency (decode prefill) is valid
        assert!(a.load_kv(Mat::zeros(100, 32), Mat::zeros(100, 32)).is_ok());
    }

    #[test]
    fn append_kv_matches_full_load_bitwise() {
        // prefill 96 rows + three ragged appends == loading all 128 at once
        let mut rng = Rng::new(81);
        let cfg = AcceleratorConfig {
            head_dim: 16,
            seq_len: 128,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        let k = Mat::from_vec(128, 16, rng.normal_vec(128 * 16));
        let v = Mat::from_vec(128, 16, rng.normal_vec(128 * 16));
        let mut grown = Accelerator::new(Arith::Hfa, cfg.clone());
        grown.load_kv(k.rows_slice(0, 96), v.rows_slice(0, 96)).unwrap();
        let mut at = 96;
        for step in [1usize, 24, 7] {
            grown.append_kv(&k.rows_slice(at, at + step), &v.rows_slice(at, at + step)).unwrap();
            at += step;
        }
        let mut full = Accelerator::new(Arith::Hfa, cfg);
        full.load_kv(k.clone(), v.clone()).unwrap();
        let q = Mat::from_vec(2, 16, rng.normal_vec(32)).round_bf16();
        let (og, sg) = grown.compute_batch(&q).unwrap();
        let (of, sf) = full.compute_batch(&q).unwrap();
        assert_eq!(og.data, of.data, "append path must be bit-exact vs full load");
        assert_eq!(sg.cycles, sf.cycles);
        // capacity guard
        assert!(grown.append_kv(&Mat::zeros(1, 16), &Mat::zeros(1, 16)).is_err());
    }

    #[test]
    fn compute_plan_bit_identical_to_per_session_batches_and_sums_timing() {
        // a fused plan over sessions of different resident lengths must
        // reproduce each session's solo compute_batch bitwise, and its
        // timing must be exactly the sum of the per-session sub-launches
        for arith in [Arith::Hfa, Arith::Fa2] {
            let mut rng = Rng::new(91);
            let cfg = AcceleratorConfig {
                head_dim: 16,
                seq_len: 128,
                kv_blocks: 4,
                parallel_queries: 1,
                freq_mhz: 500.0,
            };
            let a = Accelerator::new(arith, cfg.clone());
            let mk = |rng: &mut Rng, n: usize| {
                Arc::new(PreparedKv::new(
                    Mat::from_vec(n, 16, rng.normal_vec(n * 16)).round_bf16(),
                    Mat::from_vec(n, 16, rng.normal_vec(n * 16)).round_bf16(),
                ))
            };
            let sessions = [mk(&mut rng, 128), mk(&mut rng, 37), mk(&mut rng, 64)];
            let queries: Vec<Mat> = [3usize, 1, 2]
                .iter()
                .map(|&b| Mat::from_vec(b, 16, rng.normal_vec(b * 16)))
                .collect();
            let plan: Vec<(&Arc<PreparedKv>, &Mat)> =
                sessions.iter().zip(&queries).collect();
            let (outs, fused_stats) = a.compute_plan(&plan).unwrap();
            assert_eq!(outs.len(), 3);
            let mut solo_cycles = 0u64;
            let mut solo_reads = 0u64;
            for ((kv, q), fused_out) in plan.iter().zip(&outs) {
                let mut solo = Accelerator::new(arith, cfg.clone());
                solo.load_prepared(Arc::clone(kv)).unwrap();
                let (want, stats) = solo.compute_batch(q).unwrap();
                assert_eq!(
                    fused_out.data, want.data,
                    "{arith:?}: fused output must match the solo launch bitwise"
                );
                solo_cycles += stats.cycles;
                solo_reads += stats.sram_word_reads;
            }
            assert_eq!(fused_stats.cycles, solo_cycles, "{arith:?}: sub-launch cycles sum");
            assert_eq!(fused_stats.sram_word_reads, solo_reads, "{arith:?}");
        }
    }

    #[test]
    fn compute_plan_validates_every_entry() {
        let (a, _, _) = accel(Arith::Hfa, 16, 64, 2);
        assert!(a.compute_plan(&[]).is_err(), "empty plan");
        let good = Arc::new(PreparedKv::new(Mat::zeros(8, 16), Mat::zeros(8, 16)));
        let wrong_d = Arc::new(PreparedKv::new(Mat::zeros(8, 8), Mat::zeros(8, 8)));
        let q = Mat::zeros(1, 16);
        assert!(a.compute_plan(&[(&good, &q)]).is_ok());
        assert!(
            a.compute_plan(&[(&good, &q), (&wrong_d, &q)]).is_err(),
            "any bad session fails the whole plan"
        );
        let q_bad = Mat::zeros(1, 8);
        assert!(a.compute_plan(&[(&good, &q_bad)]).is_err(), "query dim checked per entry");
    }

    #[test]
    fn compute_is_deterministic() {
        let (a, _, _) = accel(Arith::Hfa, 16, 64, 2);
        let mut rng = Rng::new(12);
        let q = Mat::from_vec(2, 16, rng.normal_vec(32));
        let (o1, _) = a.compute_batch(&q).unwrap();
        let (o2, _) = a.compute_batch(&q).unwrap();
        assert_eq!(o1.data, o2.data);
    }

    #[test]
    fn attention_impl_dispatch_consistency() {
        // Impl::Hfa golden vs the accelerator with p=1 must agree exactly
        let (a, k, v) = accel(Arith::Hfa, 16, 64, 1);
        let mut rng = Rng::new(14);
        let q = Mat::from_vec(2, 16, rng.normal_vec(32)).round_bf16();
        let (out, _) = a.compute_batch(&q).unwrap();
        let golden = crate::attention::compute(Impl::Hfa, &q, &k, &v, None);
        assert_eq!(out.data, golden.data);
    }
}

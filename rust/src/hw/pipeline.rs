//! Cycle-level timing model of the parallel attention accelerator
//! (Fig. 2): `p` block-FAUs streaming KV sub-blocks at II=1, a vertical
//! ACC merge cascade under ready/valid flow control, and the final
//! division block.
//!
//! Latency calibration: the paper reports identical pipelined latency for
//! FA-2 and H-FA — 19/20/21 cycles for d = 32/64/128 at 500 MHz.  The
//! stage decomposition below (dot tree depth `3 + log2 d`, accumulate 4,
//! ACC 3, DIV 4) reproduces exactly those totals and is asserted in tests.

/// Pipeline depths of the accelerator's stages (identical for both
/// arithmetic variants — Section VI-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyModel {
    /// Dot-product unit: multiplier + log2(d) adder-tree levels + scale.
    pub dot_depth: u64,
    /// Fused sum/output accumulate stage (Eq. 14 / Alg. 2 lines 4-6).
    pub accum_depth: u64,
    /// One ACC merge hop in the vertical cascade.
    pub acc_depth: u64,
    /// Final division (DIV or LogDiv+conversion).
    pub div_depth: u64,
}

impl LatencyModel {
    pub fn for_head_dim(d: usize) -> LatencyModel {
        assert!(d.is_power_of_two() && d >= 4, "head dim must be a power of two");
        LatencyModel {
            dot_depth: 3 + d.ilog2() as u64,
            accum_depth: 4,
            acc_depth: 3,
            div_depth: 4,
        }
    }

    /// End-to-end pipeline fill latency for one key reaching the output.
    pub fn total(&self) -> u64 {
        self.dot_depth + self.accum_depth + self.acc_depth + self.div_depth
    }
}

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct CycleStats {
    /// Total cycles from first key fetch to last query result.
    pub cycles: u64,
    /// Query rounds executed (ceil(queries / parallel datapaths)).
    pub rounds: u64,
    /// Keys streamed by the longest FAU per round (ceil(N / p); the tail
    /// block of a ragged split streams fewer).
    pub keys_per_fau: u64,
    /// Busy unit-cycles per block type (for utilization / activity).
    pub fau_busy: u64,
    pub acc_busy: u64,
    pub div_busy: u64,
    /// Total FAU instances (p * nq).
    pub fau_units: u64,
    pub acc_units: u64,
    pub div_units: u64,
    /// SRAM word reads (K and V row elements streamed).
    pub sram_word_reads: u64,
}

impl CycleStats {
    pub fn fau_utilization(&self) -> f64 {
        self.fau_busy as f64 / (self.fau_units * self.cycles) as f64
    }

    pub fn acc_utilization(&self) -> f64 {
        if self.acc_units == 0 {
            return 0.0;
        }
        self.acc_busy as f64 / (self.acc_units * self.cycles) as f64
    }

    pub fn div_utilization(&self) -> f64 {
        self.div_busy as f64 / (self.div_units * self.cycles) as f64
    }

    /// Wall-clock at the given frequency.
    pub fn time_us(&self, freq_mhz: f64) -> f64 {
        self.cycles as f64 / freq_mhz
    }

    /// Average SRAM words read per cycle.
    pub fn sram_words_per_cycle(&self) -> f64 {
        self.sram_word_reads as f64 / self.cycles as f64
    }
}

/// Simulate computing attention for `num_queries` query vectors:
/// `d` head dim, `n` sequence length, `p` parallel KV sub-blocks, `nq`
/// replicated query datapaths.
///
/// Ready/valid cascade semantics: ACC_i fires when both its block-FAU
/// triplet and ACC_{i-1}'s result are valid; rounds pipeline back-to-back
/// (FAU state is double-buffered), so the steady-state round interval is
/// `max(keys_per_fau, acc_depth, div_depth)`.
///
/// `n` need not divide evenly into `p`: the split mirrors the functional
/// `kv_block_ranges(n, p)` partition — blocks of `ceil(n/p)` keys with a
/// shorter ragged tail (and fewer active FAUs than `p` when `n < p`),
/// which is what a mid-decode resident length looks like.  The critical
/// path follows the longest stream; identical to the seed formulas when
/// `p` divides `n`.
pub fn simulate(
    d: usize,
    n: usize,
    p: usize,
    nq: usize,
    num_queries: usize,
    lat: LatencyModel,
) -> CycleStats {
    assert!(n > 0, "cannot simulate an empty KV stream");
    let p = p.max(1);
    // longest FAU stream and the number of FAUs that actually receive
    // keys under the ragged split (== kv_block_ranges(n, p).len())
    let keys = n.div_ceil(p) as u64;
    let active_blocks = (n as u64).div_ceil(keys);
    let rounds = num_queries.div_ceil(nq) as u64;
    let merges = active_blocks.saturating_sub(1);

    // per-round phase timings relative to round start
    let fau_valid = lat.dot_depth + lat.accum_depth + keys - 1;
    let acc_valid = fau_valid + merges * lat.acc_depth;
    let done = acc_valid + lat.div_depth;

    // steady-state initiation interval between rounds
    let interval = keys.max(lat.acc_depth).max(lat.div_depth);
    let cycles = (rounds - 1) * interval + done + 1;

    let fau_units = (p * nq) as u64;
    let acc_units = (p.saturating_sub(1) * nq) as u64;
    let div_units = nq as u64;

    CycleStats {
        cycles,
        rounds,
        keys_per_fau: keys,
        // every resident key is streamed once per round per query
        // datapath; equals rounds * keys * fau_units for an even split
        fau_busy: rounds * (n as u64) * nq as u64,
        acc_busy: rounds * merges * lat.acc_depth * nq as u64,
        div_busy: rounds * lat.div_depth * div_units,
        fau_units,
        acc_units: acc_units.max(1),
        div_units,
        // each FAU reads one k row + one v row (d words each) per key;
        // the KV stream is shared across the nq query datapaths (Fig. 1:
        // same blocks of key and value vectors are reused)
        sram_word_reads: rounds * (n as u64) * (2 * d as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_matches_paper_totals() {
        // Section VI-C: 19, 20, 21 cycles for d = 32, 64, 128
        assert_eq!(LatencyModel::for_head_dim(32).total(), 19);
        assert_eq!(LatencyModel::for_head_dim(64).total(), 20);
        assert_eq!(LatencyModel::for_head_dim(128).total(), 21);
    }

    #[test]
    fn single_query_dominated_by_streaming() {
        let lat = LatencyModel::for_head_dim(64);
        let s = simulate(64, 1024, 1, 1, 1, lat);
        // one FAU streams all 1024 keys
        assert_eq!(s.keys_per_fau, 1024);
        assert!(s.cycles >= 1024 && s.cycles < 1024 + 40, "{}", s.cycles);
    }

    #[test]
    fn fig8_speedup_about_6x_at_8_blocks() {
        // paper Fig. 8(a): ~6x runtime reduction from 1 -> 8 KV blocks
        let lat = LatencyModel::for_head_dim(64);
        let t1 = simulate(64, 1024, 1, 1, 1, lat).cycles as f64;
        let t8 = simulate(64, 1024, 8, 1, 1, lat).cycles as f64;
        let speedup = t1 / t8;
        assert!((5.0..7.5).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn speedup_plateaus_with_more_blocks() {
        // marginal gain per doubling must shrink (merge overhead grows)
        let lat = LatencyModel::for_head_dim(64);
        let t: Vec<f64> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&p| simulate(64, 1024, p, 1, 1, lat).cycles as f64)
            .collect();
        let g1 = t[0] / t[1];
        let g4 = t[3] / t[4];
        assert!(g1 > g4, "gains should diminish: {g1} vs {g4}");
    }

    #[test]
    fn rounds_pipeline_with_stream_interval() {
        let lat = LatencyModel::for_head_dim(64);
        let one = simulate(64, 1024, 4, 1, 1, lat).cycles;
        let ten = simulate(64, 1024, 4, 1, 10, lat).cycles;
        // 9 extra rounds at 256-cycle interval
        assert_eq!(ten - one, 9 * 256);
    }

    #[test]
    fn parallel_query_datapaths_cut_rounds() {
        let lat = LatencyModel::for_head_dim(64);
        let s1 = simulate(64, 1024, 4, 1, 16, lat);
        let s4 = simulate(64, 1024, 4, 4, 16, lat);
        assert_eq!(s1.rounds, 16);
        assert_eq!(s4.rounds, 4);
        assert!(s4.cycles < s1.cycles);
    }

    #[test]
    fn utilization_bounded() {
        let lat = LatencyModel::for_head_dim(32);
        let s = simulate(32, 1024, 4, 2, 64, lat);
        for u in [s.fau_utilization(), s.acc_utilization(), s.div_utilization()] {
            assert!((0.0..=1.0).contains(&u), "utilization {u}");
        }
        // FAUs are the workhorse: near-full utilization in steady state
        assert!(s.fau_utilization() > 0.8, "{}", s.fau_utilization());
    }

    #[test]
    fn ragged_lengths_simulate_without_panicking() {
        // mid-decode residency: n not divisible by p, and n < p
        let lat = LatencyModel::for_head_dim(8);
        let s = simulate(8, 25, 4, 1, 2, lat);
        assert_eq!(s.keys_per_fau, 7); // ceil(25/4), the longest stream
        assert_eq!(s.sram_word_reads, 2 * 2 * 25 * 8); // 2 rounds x 25 rows
        assert!(s.cycles > 0);
        let tiny = simulate(8, 3, 8, 1, 1, lat);
        assert_eq!(tiny.keys_per_fau, 1); // 3 active FAUs of 1 key each
        assert!(tiny.acc_utilization() <= 1.0 && tiny.fau_utilization() <= 1.0);
        // growing the resident length must not shorten the modelled time
        let shorter = simulate(8, 24, 4, 1, 1, lat).cycles;
        let longer = simulate(8, 25, 4, 1, 1, lat).cycles;
        assert!(longer >= shorter, "{longer} < {shorter}");
        // divisible case unchanged vs the seed formula: keys = n/p
        let even = simulate(8, 24, 4, 1, 1, lat);
        assert_eq!(even.keys_per_fau, 6);
        assert_eq!(even.fau_busy, 24);
    }

    #[test]
    fn sram_reads_match_streamed_rows() {
        let lat = LatencyModel::for_head_dim(64);
        let s = simulate(64, 1024, 4, 1, 1, lat);
        // whole K and V matrices read once: 2 * 1024 rows * 64 words
        assert_eq!(s.sram_word_reads, 2 * 1024 * 64);
    }
}

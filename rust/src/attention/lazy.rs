//! Lazy-softmax-division attention (paper Alg. 1): two passes over the
//! keys — find the global max first, then accumulate `e^{s_i - m_N} v_i`
//! and the exponential sum, dividing once at the end.  This is the
//! baseline FlashAttention-2 improves on (no second pass needed).

use crate::tensor::{dot_f32, Mat};

/// Alg. 1 in f32, matching the hardware evaluation order.
pub fn attention(q: &Mat, k: &Mat, v: &Mat, scale: Option<f32>, mask: Option<&[bool]>) -> Mat {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    let dv = v.cols;
    let scale = scale.unwrap_or(1.0 / (d as f32).sqrt());
    let mut out = Mat::zeros(b, dv);

    for bi in 0..b {
        let qrow = q.row(bi);
        let valid = |i: usize| mask.map(|m| m[bi * n + i]).unwrap_or(true);

        // pass 1 (lines 2-5): scores and running max
        let mut scores = vec![f32::NEG_INFINITY; n];
        let mut m = f32::NEG_INFINITY;
        for i in 0..n {
            if valid(i) {
                scores[i] = dot_f32(qrow, k.row(i)) * scale;
                m = m.max(scores[i]);
            }
        }

        // pass 2 (lines 6-10): fused accumulation with the *final* max
        let mut ell = 0.0f32;
        let mut acc = vec![0.0f32; dv];
        for i in 0..n {
            if !valid(i) {
                continue;
            }
            let f = (scores[i] - m).exp();
            ell += f;
            for (a, &vv) in acc.iter_mut().zip(v.row(i)) {
                *a += f * vv;
            }
        }
        // line 11: single deferred division.  A fully-masked row has
        // `ell == 0` and an all-zero accumulator; 0/0 would be NaN, so
        // define the output as the zero row (out is pre-zeroed).
        if ell == 0.0 {
            continue;
        }
        for (j, a) in acc.iter().enumerate() {
            out.set(bi, j, a / ell);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::proptest::{check, Rng};

    #[test]
    fn matches_exact_attention() {
        check(
            "lazy == exact",
            17,
            25,
            |rng: &mut Rng| {
                let (b, n, d) = (1 + rng.below(4) as usize, 8 + rng.below(56) as usize, 8usize);
                (
                    Mat::from_vec(b, d, rng.normal_vec(b * d)),
                    Mat::from_vec(n, d, rng.normal_vec(n * d)),
                    Mat::from_vec(n, d, rng.normal_vec(n * d)),
                )
            },
            |(q, k, v)| {
                let ex = exact::attention(q, k, v, None, None);
                let lz = attention(q, k, v, None, None);
                let diff = ex.max_abs_diff(&lz);
                if diff < 1e-4 {
                    Ok(())
                } else {
                    Err(format!("diff {diff}"))
                }
            },
        );
    }

    #[test]
    fn single_key_is_identity() {
        let q = Mat::from_vec(1, 2, vec![0.3, -0.7]);
        let k = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        let v = Mat::from_vec(1, 2, vec![42.0, -7.0]);
        let o = attention(&q, &k, &v, None, None);
        assert_eq!(o.data, vec![42.0, -7.0]);
    }
}

//! Exact softmax attention — the f64 oracle every approximation is
//! measured against (paper Section II-A).

use crate::tensor::{dot_f32, Mat};

/// `softmax(q k^T * scale) v` with safe-softmax max subtraction, f64
/// accumulation.  `mask`: row-major `(B, N)` bools, true = attend.
pub fn attention(q: &Mat, k: &Mat, v: &Mat, scale: Option<f32>, mask: Option<&[bool]>) -> Mat {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    assert_eq!(k.cols, d);
    assert_eq!(v.rows, n);
    let scale = scale.unwrap_or(1.0 / (d as f32).sqrt()) as f64;
    let dv = v.cols;
    let mut out = Mat::zeros(b, dv);

    for bi in 0..b {
        let qrow = q.row(bi);
        let valid = |i: usize| mask.map(|m| m[bi * n + i]).unwrap_or(true);
        // scores + max
        let mut scores = vec![f64::NEG_INFINITY; n];
        let mut mx = f64::NEG_INFINITY;
        for i in 0..n {
            if valid(i) {
                scores[i] = dot_f32(qrow, k.row(i)) as f64 * scale;
                mx = mx.max(scores[i]);
            }
        }
        // weights
        let mut den = 0.0f64;
        let mut acc = vec![0.0f64; dv];
        for i in 0..n {
            if !valid(i) {
                continue;
            }
            let w = (scores[i] - mx).exp();
            den += w;
            for (a, &vv) in acc.iter_mut().zip(v.row(i)) {
                *a += w * vv as f64;
            }
        }
        // fully-masked row: den == 0 would give 0/0 = NaN; the defined
        // output is the zero row (out is pre-zeroed)
        if den == 0.0 {
            continue;
        }
        for (j, a) in acc.iter().enumerate() {
            out.set(bi, j, (a / den) as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_scores_average_values() {
        // q orthogonal to all k -> all scores 0 -> softmax uniform
        let q = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let k = Mat::from_vec(4, 2, vec![1., 0., 0., 1., -1., 0., 0., -1.]);
        let v = Mat::from_fn(4, 2, |r, c| (r * 2 + c) as f32);
        let o = attention(&q, &k, &v, None, None);
        assert!((o.at(0, 0) - 3.0).abs() < 1e-6); // mean of 0,2,4,6
        assert!((o.at(0, 1) - 4.0).abs() < 1e-6); // mean of 1,3,5,7
    }

    #[test]
    fn peaked_scores_select_value() {
        // one key matches q strongly -> output ~ its value
        let q = Mat::from_vec(1, 2, vec![10.0, 0.0]);
        let k = Mat::from_vec(2, 2, vec![10.0, 0.0, -10.0, 0.0]);
        let v = Mat::from_vec(2, 2, vec![1.0, 2.0, -5.0, -6.0]);
        let o = attention(&q, &k, &v, Some(1.0), None);
        assert!((o.at(0, 0) - 1.0).abs() < 1e-6);
        assert!((o.at(0, 1) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn safe_softmax_handles_huge_scores() {
        let q = Mat::from_vec(1, 1, vec![1000.0]);
        let k = Mat::from_vec(2, 1, vec![1.0, 0.9]);
        let v = Mat::from_vec(2, 1, vec![1.0, 0.0]);
        let o = attention(&q, &k, &v, Some(1.0), None);
        assert!(o.at(0, 0).is_finite());
        assert!(o.at(0, 0) > 0.999);
    }

    #[test]
    fn mask_excludes_keys() {
        let q = Mat::from_vec(1, 1, vec![0.0]);
        let k = Mat::from_vec(3, 1, vec![1.0, 1.0, 1.0]);
        let v = Mat::from_vec(3, 1, vec![1.0, 100.0, 3.0]);
        let mask = vec![true, false, true];
        let o = attention(&q, &k, &v, None, Some(&mask));
        assert!((o.at(0, 0) - 2.0).abs() < 1e-6); // mean of 1 and 3
    }
}

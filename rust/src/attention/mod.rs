//! Algorithm-level golden models of attention.
//!
//! * [`exact`] — textbook softmax attention (f64 oracle).
//! * [`lazy`]  — lazy-softmax-division attention (paper Alg. 1).
//! * [`fa2`]   — FlashAttention-2 streaming recurrence (paper Alg. 2), f32.
//! * [`hfa`]   — the H-FA hybrid float/log datapath (Eqs. 14-19), both the
//!   bit-exact integer path (mirrors the Pallas kernel) and the functional
//!   f64 path with per-approximation ablation switches (Table III).
//! * [`merge`] — multi-KV-block partial-result merging (Eqs. 1 and 16).
//! * [`prepared`] — the prepared-KV execution engine: V resident in SoA
//!   LNS lanes, zero-copy block views, persistent-pool query fan-out
//!   (the serving hot path).
//! * [`kernel`] — the query-tiled, two-axis-parallel micro-kernel the
//!   prepared engine runs on: K/V streamed once per query tile, the
//!   `(query-tile x KV-block)` grid fanned out over the pool (Fig. 2's
//!   two parallel axes), deterministic in-block-order Eq. 16 merge.

pub mod exact;
pub mod fa2;
pub mod hfa;
pub mod kernel;
pub mod lazy;
pub mod merge;
pub mod prepared;

pub use prepared::PreparedKv;

use crate::Mat;

/// Which attention implementation to run (CLI / eval suite selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Impl {
    Exact,
    Lazy,
    Fa2,
    Hfa,
}

impl Impl {
    // not the FromStr trait: this is a CLI selector with anyhow errors
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> anyhow::Result<Impl> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "exact" => Impl::Exact,
            "lazy" => Impl::Lazy,
            "fa2" => Impl::Fa2,
            "hfa" => Impl::Hfa,
            other => anyhow::bail!("unknown attention impl {other:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Impl::Exact => "exact",
            Impl::Lazy => "lazy",
            Impl::Fa2 => "fa2",
            Impl::Hfa => "hfa",
        }
    }
}

/// Dispatch: `q (B,d)`, `k/v (N,d)`, optional `(B,N)` boolean mask
/// (true = attend), default scale `1/sqrt(d)`.
pub fn compute(imp: Impl, q: &Mat, k: &Mat, v: &Mat, mask: Option<&[bool]>) -> Mat {
    match imp {
        Impl::Exact => exact::attention(q, k, v, None, mask),
        Impl::Lazy => lazy::attention(q, k, v, None, mask),
        Impl::Fa2 => fa2::attention(q, k, v, None, mask),
        Impl::Hfa => hfa::attention(q, k, v, None, mask, &mut None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Rng;

    fn rand_mats(rng: &mut Rng, b: usize, n: usize, d: usize) -> (Mat, Mat, Mat) {
        (
            Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        )
    }

    #[test]
    fn all_impls_agree_on_shape() {
        let mut rng = Rng::new(11);
        let (q, k, v) = rand_mats(&mut rng, 3, 32, 16);
        for imp in [Impl::Exact, Impl::Lazy, Impl::Fa2, Impl::Hfa] {
            let o = compute(imp, &q, &k, &v, None);
            assert_eq!((o.rows, o.cols), (3, 16), "{imp:?}");
        }
    }

    #[test]
    fn float_impls_numerically_equal() {
        // exact, lazy and fa2 are the same function up to f32 rounding
        let mut rng = Rng::new(5);
        let (q, k, v) = rand_mats(&mut rng, 4, 64, 32);
        let ex = compute(Impl::Exact, &q, &k, &v, None);
        let lz = compute(Impl::Lazy, &q, &k, &v, None);
        let fa = compute(Impl::Fa2, &q, &k, &v, None);
        assert!(ex.max_abs_diff(&lz) < 1e-4, "lazy {}", ex.max_abs_diff(&lz));
        assert!(ex.max_abs_diff(&fa) < 1e-4, "fa2 {}", ex.max_abs_diff(&fa));
    }

    #[test]
    fn hfa_tracks_exact_for_positive_values() {
        // all-positive V: no signed cancellation -> H-FA within a few %
        let mut rng = Rng::new(9);
        let (q, k, mut v) = rand_mats(&mut rng, 4, 64, 32);
        for x in &mut v.data {
            *x = x.abs().max(0.05);
        }
        let v = v.round_bf16();
        let ex = compute(Impl::Exact, &q, &k, &v, None);
        let hf = compute(Impl::Hfa, &q, &k, &v, None);
        let rel = hf.rel_rms(&ex);
        assert!(rel < 0.08, "rel rms {rel}");
    }

    #[test]
    fn fully_masked_rows_return_zero_not_nan() {
        // regression: a row whose every key is masked used to divide 0/0
        // (NaN) in exact/lazy/fa2; H-FA's LogDiv already defined it as
        // zero.  All four variants (and the prepared serving path) must
        // return a zero row while leaving other rows untouched.
        let mut rng = Rng::new(33);
        let (q, k, v) = rand_mats(&mut rng, 2, 8, 4);
        let mut mask = vec![true; 2 * 8];
        for slot in mask.iter_mut().take(8) {
            *slot = false; // row 0: nothing to attend to
        }
        for imp in [Impl::Exact, Impl::Lazy, Impl::Fa2, Impl::Hfa] {
            let o = compute(imp, &q, &k, &v, Some(&mask));
            assert_eq!(o.row(0), &[0.0f32; 4][..], "{imp:?}: fully-masked row must be zero");
            assert!(
                o.row(1).iter().all(|x| x.is_finite()),
                "{imp:?}: unmasked row went non-finite"
            );
            // the unmasked row must be unaffected by the masked one
            let solo = compute(imp, &q.rows_slice(1, 2), &k, &v, Some(&mask[8..]));
            assert_eq!(o.row(1), solo.row(0), "{imp:?}");
        }
        let kv = PreparedKv::new(k.clone(), v.clone());
        let o = kv.attention(&q, None, Some(&mask));
        assert_eq!(o.row(0), &[0.0f32; 4][..], "prepared path fully-masked row");

        // the PR 4 kernel variants were never pinned on this edge: a
        // query whose every resident row is masked must come out of the
        // tile micro-kernel as the empty state (m = -inf, zero lanes),
        // finalizing to a zero row — and the grid merge of *several*
        // such empty per-block states (the -inf-minus--inf quantizer
        // warmup case) must stay zero, not NaN
        let tiled = kernel::tile_states_prepared(&kv, &q, (0, 2), (0, 8), 0.5, Some(&mask));
        assert_eq!(tiled[0].m, f32::NEG_INFINITY, "tile: fully-masked query never stepped");
        assert_eq!(tiled[0].finalize(), vec![0.0; 4], "tile_states_prepared fully-masked row");
        assert!(tiled[1].finalize().iter().all(|x| x.is_finite()));
        let v_lns = prepared::convert_values(&v);
        let borrowed =
            kernel::tile_states_borrowed(&q, &k, &v_lns, (0, 2), (0, 8), 0.5, Some(&mask));
        assert_eq!(borrowed[0].finalize(), vec![0.0; 4], "tile_states_borrowed");
        let blocks = [(0usize, 3usize), (3, 6), (6, 8)];
        let grid = kernel::grid_states_multi(
            &[kernel::GridJob { kv: &kv, q: &q, blocks: &blocks, scale: 0.5, mask: Some(&mask) }],
            kernel::DEFAULT_QUERY_TILE,
        )
        .pop()
        .unwrap();
        assert_eq!(
            grid[0].finalize(),
            vec![0.0; 4],
            "grid merge of all-masked per-block states must be zero, not NaN"
        );
        assert!(grid[1].finalize().iter().all(|x| x.is_finite()));

        // zero keys at all (empty mask domain) is the same edge for the
        // fa2/hfa state finalizers
        let st = fa2::Fa2State::new(4);
        assert_eq!(st.finalize(), vec![0.0; 4]);
        let hst = hfa::HfaState::new(4);
        assert_eq!(hst.finalize(), vec![0.0; 4]);
    }

    #[test]
    fn mask_restricts_attention() {
        let mut rng = Rng::new(21);
        let (q, k, v) = rand_mats(&mut rng, 2, 16, 8);
        // mask out all but first 4 keys for row 0, all keys valid row 1
        let mut mask = vec![true; 2 * 16];
        for i in 4..16 {
            mask[i] = false;
        }
        for imp in [Impl::Exact, Impl::Lazy, Impl::Fa2, Impl::Hfa] {
            let o = compute(imp, &q, &k, &v, Some(&mask));
            let k4 = k.rows_slice(0, 4);
            let v4 = v.rows_slice(0, 4);
            let q0 = q.rows_slice(0, 1);
            // row 0 must equal attention over only the first 4 keys,
            // computed with the *same* scale 1/sqrt(d)
            let o4 = match imp {
                Impl::Exact => exact::attention(&q0, &k4, &v4, None, None),
                Impl::Lazy => lazy::attention(&q0, &k4, &v4, None, None),
                Impl::Fa2 => fa2::attention(&q0, &k4, &v4, None, None),
                Impl::Hfa => hfa::attention(&q0, &k4, &v4, None, None, &mut None),
            };
            let diff = (0..8)
                .map(|j| (o.at(0, j) - o4.at(0, j)).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-5, "{imp:?} masked row mismatch {diff}");
        }
    }
}

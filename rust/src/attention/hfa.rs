//! H-FA: the paper's hybrid float/log-domain FlashAttention-2 datapath
//! (Sections IV-V), in two tiers:
//!
//! * the **bit-exact integer path** — Q9.7 LNS accumulation with
//!   Mitchell's approximation and the 8-segment PWL, identical to the
//!   Pallas kernel and the python `hfa_attention_int` spec (pinned by
//!   golden vectors);
//! * the **functional f64 path** with one switch per approximation
//!   source, backing the Table III error-attribution study.

// Always-std atomics (`counter`): `static` initializers need const `new`,
// which loom's types lack, and this is a monotonic conversion counter,
// not a synchronization protocol.
use crate::sync::counter::{AtomicU64, Ordering};

use crate::arith::bf16::Bf16;
use crate::arith::fix::{quant_diff_q7, CLAMP_LO, FRAC_ONE, LOG2E_F32};
use crate::arith::lns::{from_bf16_traced, lns_add_traced, Lns, LnsVec};
use crate::arith::mitchell::MitchellHistogram;
use crate::arith::pwl;
use crate::tensor::{dot_f32, Mat};

use super::prepared;

/// Partial H-FA state for one query: the `(m, sign, log|O|)` triplet of
/// Fig. 4, where `O = [ell, o]` has `d+1` LNS lanes (lane 0 = ell).
#[derive(Clone, Debug)]
pub struct HfaState {
    pub m: f32,
    pub acc: LnsVec,
}

impl HfaState {
    pub fn new(dv: usize) -> HfaState {
        HfaState { m: f32::NEG_INFINITY, acc: LnsVec::zeros(dv + 1) }
    }

    /// One FAU step (Eq. 14): score `s` (f32, float domain) and the value
    /// row already converted to LNS (`d+1` lanes, lane 0 = LNS one).
    #[inline]
    pub fn step(&mut self, s: f32, v_lns: &LnsVec, hist: &mut Option<&mut MitchellHistogram>) {
        let m_new = self.m.max(s);
        let dm_q = quant_diff_q7(self.m - m_new); // (m_{i-1} - m_i) log2 e
        let ds_q = quant_diff_q7(s - m_new); //      (s_i - m_i) log2 e
        self.m = m_new;
        if hist.is_none() {
            // hot path (see EXPERIMENTS.md §Perf): slice-wise, no Option
            // checks or struct shuffling per lane — bit-identical results
            step_lanes_fast(
                &mut self.acc.signs,
                &mut self.acc.logs,
                &v_lns.signs,
                &v_lns.logs,
                dm_q,
                ds_q,
            );
            return;
        }
        for i in 0..self.acc.len() {
            let a = self.acc.get(i).scaled(dm_q);
            let b = v_lns.get(i).scaled(ds_q);
            let r = lns_add_traced(a, b, hist.as_deref_mut());
            self.acc.set(i, r);
        }
    }

    /// [`HfaState::step`] on raw sign/log lane slices — the prepared-KV
    /// hot path.  Bit-identical to `step` with `hist = None`: same
    /// quantizer, same `step_lanes_fast` kernel.
    #[inline]
    pub fn step_slices(&mut self, s: f32, v_signs: &[i32], v_logs: &[i32]) {
        let m_new = self.m.max(s);
        let dm_q = quant_diff_q7(self.m - m_new);
        let ds_q = quant_diff_q7(s - m_new);
        self.m = m_new;
        step_lanes_fast(&mut self.acc.signs, &mut self.acc.logs, v_signs, v_logs, dm_q, ds_q);
    }

    /// LogDiv + back-conversion (Eqs. 15, 22): divide every `o` lane by
    /// the `ell` lane with a fixed-point subtraction, convert to BF16.
    pub fn finalize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.acc.len() - 1];
        self.finalize_into(&mut out);
        out
    }

    /// [`HfaState::finalize`] writing straight into a caller-provided
    /// `dv`-wide slice (e.g. the output `Mat`'s row) — no per-query
    /// `Vec` allocation on the serving path.
    pub fn finalize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len() + 1, self.acc.len(), "finalize_into width mismatch");
        let ell = self.acc.get(0);
        for (j, slot) in out.iter_mut().enumerate() {
            let o = self.acc.get(j + 1);
            *slot = if o.is_zero() {
                0.0
            } else {
                Lns { sign: o.sign ^ ell.sign, log: o.log - ell.log }.to_bf16().to_f32()
            };
        }
    }
}

/// Tile variant of [`HfaState::step_slices`]: advance a tile of
/// per-query accumulators past **one** streamed key — `scores[t]` is
/// query `t`'s score against that key, and the value row's lane planes
/// are loaded once for the whole tile instead of once per query (the
/// K/V-stream amortization of `attention::kernel`).  Bit-identical to
/// the same `step_slices` calls issued per query: each state's
/// quantizer sees only its own score and its own running max.
pub fn step_tile_slices(
    states: &mut [HfaState],
    scores: &[f32],
    v_signs: &[i32],
    v_logs: &[i32],
) {
    debug_assert_eq!(states.len(), scores.len());
    for (st, &s) in states.iter_mut().zip(scores) {
        st.step_slices(s, v_signs, v_logs);
    }
}

/// Slice-wise Eq.-14 lane update — the profiled hot loop of the whole
/// emulation stack (one call per key per query).  Semantically identical
/// to `Lns::scaled` + `lns_add` per lane; kept branch-light so LLVM can
/// keep everything in registers.
#[inline]
fn step_lanes_fast(
    acc_s: &mut [i32],
    acc_l: &mut [i32],
    v_s: &[i32],
    v_l: &[i32],
    dm_q: i32,
    ds_q: i32,
) {
    use crate::arith::fix::{is_log_zero, LOG_ZERO};
    let it = acc_s
        .iter_mut()
        .zip(acc_l.iter_mut())
        .zip(v_s.iter().zip(v_l.iter()));
    for ((sa_m, la_m), (&sb, &lb)) in it {
        let (sa, la) = (*sa_m, *la_m);
        let a_zero = is_log_zero(la);
        let b_zero = is_log_zero(lb);
        if a_zero | b_zero {
            if a_zero & b_zero {
                *la_m = LOG_ZERO;
                *sa_m = 0;
            } else if a_zero {
                *sa_m = sb;
                *la_m = lb + ds_q;
            } else {
                *la_m = la + dm_q;
            }
            continue;
        }
        let a = la + dm_q;
        let b = lb + ds_q;
        let dlt = a - b;
        let dabs = dlt.abs();
        let r = pwl::pow2_neg_q7(dabs);
        let mx = if dlt > 0 { a } else { b };
        *la_m = if sa == sb { mx + r } else { mx - r };
        *sa_m = if dlt > 0 { sa } else { sb };
    }
}

/// Process-wide count of value rows pushed through [`value_to_lns`].
/// The prepared-KV serving path pays this once per session load; the
/// regression test `rust/tests/kv_prepare_once.rs` pins that property.
static VALUE_ROWS_CONVERTED: AtomicU64 = AtomicU64::new(0);

/// How many value rows have been linear->log converted so far (across
/// every path: prepared builds, traced runs, golden replays).
pub fn value_conversion_count() -> u64 {
    // ordering: Relaxed — monotonic counter read for reporting; no other
    // memory is published through it.
    VALUE_ROWS_CONVERTED.load(Ordering::Relaxed)
}

/// Convert a value row (f32, BF16-valued) to `d+1` LNS lanes with the
/// prepended constant-one lane (Eq. 12's `V = [1, v]`).
pub fn value_to_lns(vrow: &[f32], hist: &mut Option<&mut MitchellHistogram>) -> LnsVec {
    // ordering: Relaxed — counter increment only; totals are read after
    // the converting calls return (program order suffices).
    VALUE_ROWS_CONVERTED.fetch_add(1, Ordering::Relaxed);
    let mut out = LnsVec::zeros(vrow.len() + 1);
    out.set(0, Lns { sign: 0, log: 0 }); // LNS of 1.0
    for (i, &x) in vrow.iter().enumerate() {
        out.set(i + 1, from_bf16_traced(Bf16::from_f32(x), hist.as_deref_mut()));
    }
    out
}

/// Bit-exact H-FA attention.  `q (B,d)`, `k/v (N,d)` (f32 storage, BF16
/// values), optional mask, optional Fig.-5 histogram recorder.
pub fn attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    scale: Option<f32>,
    mask: Option<&[bool]>,
    hist: &mut Option<&mut MitchellHistogram>,
) -> Mat {
    let states = partial_states(q, k, v, scale, mask, hist);
    finalize_states(&states, v.cols)
}

/// Inner loop only (no division): one KV block's `(m, sign, log)` triplet
/// per query.
///
/// The untraced path prepares V once into SoA LNS lanes and fans queries
/// out over the persistent worker pool (`runtime::pool`) — no per-call
/// thread spawns.  With a histogram attached it runs the serial traced
/// datapath so every Mitchell input is recorded (Fig. 5).  Both paths are
/// bit-identical.
pub fn partial_states(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    scale: Option<f32>,
    mask: Option<&[bool]>,
    hist: &mut Option<&mut MitchellHistogram>,
) -> Vec<HfaState> {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    assert_eq!(k.cols, d);

    if hist.is_none() {
        let v_lns = prepared::convert_values(v);
        let scale = prepared::resolve_scale(scale, d);
        return prepared::partial_states_borrowed(q, k, &v_lns, 0, n, scale, mask);
    }

    // traced path (Fig. 5 instrumentation): serial, per-lane Option checks
    let scale = prepared::resolve_scale(scale, d);
    let v_lns: Vec<LnsVec> = (0..n).map(|i| value_to_lns(v.row(i), hist)).collect();
    let mut states = Vec::with_capacity(b);
    for bi in 0..b {
        let mut st = HfaState::new(v.cols);
        let qrow = q.row(bi);
        for i in 0..n {
            if mask.map(|m| !m[bi * n + i]).unwrap_or(false) {
                continue;
            }
            let s = dot_f32(qrow, k.row(i)) * scale;
            st.step(s, &v_lns[i], hist);
        }
        states.push(st);
    }
    states
}

/// Replay the LNS pipeline from a precomputed score matrix `(B, N)` —
/// used by golden-vector replay to pin bit-exactness independent of
/// dot-product association order.
pub fn attention_from_scores(scores: &Mat, v: &Mat) -> Mat {
    let (b, n) = (scores.rows, scores.cols);
    let v_lns = prepared::convert_values(v);
    let mut states: Vec<HfaState> = (0..b).map(|_| HfaState::new(v.cols)).collect();
    for (bi, st) in states.iter_mut().enumerate() {
        for i in 0..n {
            st.step_slices(scores.at(bi, i), v_lns.row_signs(i), v_lns.row_logs(i));
        }
    }
    finalize_states(&states, v.cols)
}

pub(crate) fn finalize_states(states: &[HfaState], dv: usize) -> Mat {
    let mut out = Mat::zeros(states.len(), dv);
    for (bi, st) in states.iter().enumerate() {
        // LogDiv straight into the output row — no per-query Vec
        st.finalize_into(out.row_mut(bi));
    }
    out
}

/// 2D-parallel H-FA (Fig. 2): split KV into `num_blocks`, run independent
/// partial FAUs, merge with the log-domain ACC (Eq. 16), then LogDiv.
///
/// `num_blocks` need not divide `k.rows`: the tail block is simply
/// shorter (`prepared::kv_block_ranges`), matching the seed partition
/// exactly in the divisible case.  Values are converted once for the
/// whole call, not once per block.
pub fn attention_blocked(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    num_blocks: usize,
    scale: Option<f32>,
    hist: &mut Option<&mut MitchellHistogram>,
) -> Mat {
    if hist.is_none() {
        // convert once for the whole call, then merge over block ranges
        let v_lns = prepared::convert_values(v);
        let states = prepared::blocked_states(q, k, &v_lns, num_blocks, scale);
        return finalize_states(&states, v.cols);
    }
    let mut acc: Option<Vec<HfaState>> = None;
    for (lo, hi) in prepared::kv_block_ranges(k.rows, num_blocks) {
        let kb = k.rows_slice(lo, hi);
        let vb = v.rows_slice(lo, hi);
        let st = partial_states(q, &kb, &vb, scale, None, hist);
        acc = Some(match acc {
            None => st,
            Some(prev) => prev
                .into_iter()
                .zip(st)
                .map(|(a, b)| super::merge::merge_hfa(&a, &b, hist))
                .collect(),
        });
    }
    let states = acc.unwrap_or_else(|| (0..q.rows).map(|_| HfaState::new(v.cols)).collect());
    finalize_states(&states, v.cols)
}

// ---------------------------------------------------------------------------
// Functional f64 path with ablation switches (Table III)
// ---------------------------------------------------------------------------

/// Ablation switches for the three H-FA error sources.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EmuConfig {
    /// (a) Q9.7 fixed-point quantization + [-15, 0] clamp of score diffs.
    pub quant: bool,
    /// (b) Mitchell's `log2(1 +- x) ~= +-x` (Eqs. 17, 18, 22).
    pub mitchell: bool,
    /// (c) 8-segment PWL for `2^-f` (Eq. 19).
    pub pwl: bool,
}

impl Default for EmuConfig {
    fn default() -> Self {
        EmuConfig { quant: true, mitchell: true, pwl: true }
    }
}

impl EmuConfig {
    pub fn all_on() -> Self {
        Self::default()
    }

    pub fn all_off() -> Self {
        EmuConfig { quant: false, mitchell: false, pwl: false }
    }
}

fn q_emu(x: f64, cfg: EmuConfig) -> f64 {
    // score-difference quantization (natural-log -> log2 units)
    let x = if x.is_nan() { f64::from(CLAMP_LO) } else { x };
    if cfg.quant {
        let c = x.clamp(CLAMP_LO as f64, 0.0);
        let t = (c as f32) * LOG2E_F32;
        ((t as f64) * FRAC_ONE as f64).floor() / FRAC_ONE as f64
    } else {
        x * LOG2E_F32 as f64
    }
}

fn log2_value_emu(v: Bf16, cfg: EmuConfig) -> (i32, f64) {
    if v.is_zero_or_subnormal() {
        return (v.sign() as i32, f64::NEG_INFINITY);
    }
    let e = v.exponent() as f64 - 127.0;
    let mant = v.mantissa() as f64 / 128.0;
    let l = if cfg.mitchell { e + mant } else { e + (1.0 + mant).log2() };
    (v.sign() as i32, l)
}

fn pow2_neg_emu(d: f64, cfg: EmuConfig) -> f64 {
    let d = if d.is_finite() { d } else { 1e9 };
    if cfg.pwl {
        pwl::pow2_neg_pwl_f64(d)
    } else {
        2f64.powf(-d.min(1000.0))
    }
}

fn lns_add_emu(sa: i32, a: f64, sb: i32, b: f64, cfg: EmuConfig) -> (i32, f64) {
    if a == f64::NEG_INFINITY {
        if b == f64::NEG_INFINITY {
            return (0, f64::NEG_INFINITY);
        }
        return (sb, b);
    }
    if b == f64::NEG_INFINITY {
        return (sa, a);
    }
    let dist = (a - b).abs();
    let x = pow2_neg_emu(dist, cfg);
    let mx = a.max(b);
    let delta = if cfg.mitchell {
        if sa == sb { x } else { -x }
    } else {
        let lin: f64 = if sa == sb { 1.0 + x } else { (1.0 - x).max(1e-300) };
        lin.log2()
    };
    let sign = if a > b { sa } else { sb };
    (sign, mx + delta)
}

/// Functional f64 H-FA with ablation switches (Table III driver).
pub fn attention_emu(q: &Mat, k: &Mat, v: &Mat, cfg: EmuConfig, scale: Option<f32>) -> Mat {
    attention_emu_masked(q, k, v, cfg, scale, None)
}

/// `attention_emu` with an optional `(B, N)` mask (true = attend).
pub fn attention_emu_masked(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    cfg: EmuConfig,
    scale: Option<f32>,
    mask: Option<&[bool]>,
) -> Mat {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    let dv = v.cols;
    let scale = scale.unwrap_or(1.0 / (d as f32).sqrt());

    // value rows (with prepended 1) in the functional log domain
    let v_log: Vec<Vec<(i32, f64)>> = (0..n)
        .map(|i| {
            let mut row = Vec::with_capacity(dv + 1);
            row.push((0, 0.0));
            for &x in v.row(i) {
                row.push(log2_value_emu(Bf16::from_f32(x), cfg));
            }
            row
        })
        .collect();

    let mut out = Mat::zeros(b, dv);
    for bi in 0..b {
        let qrow = q.row(bi);
        let mut m = f32::NEG_INFINITY;
        let mut signs = vec![0i32; dv + 1];
        let mut logs = vec![f64::NEG_INFINITY; dv + 1];
        for i in 0..n {
            if mask.map(|m| !m[bi * n + i]).unwrap_or(false) {
                continue;
            }
            let s = dot_f32(qrow, k.row(i)) * scale;
            let m_new = m.max(s);
            let dm = q_emu((m - m_new) as f64, cfg);
            let ds = q_emu((s - m_new) as f64, cfg);
            for lane in 0..=dv {
                let a = logs[lane] + dm;
                let (sv, lv) = v_log[i][lane];
                let bb = lv + ds;
                let (sn, ln) = lns_add_emu(signs[lane], a, sv, bb, cfg);
                signs[lane] = sn;
                logs[lane] = ln;
            }
            m = m_new;
        }
        for j in 0..dv {
            let la = logs[j + 1] - logs[0];
            let sgn = signs[j + 1] ^ signs[0];
            let mag = if la == f64::NEG_INFINITY || la.is_nan() {
                0.0
            } else if cfg.mitchell {
                // Eq. 22 back-conversion: 2^(I+F) ~= 2^I (1+F)
                let ip = la.floor();
                2f64.powf(ip) * (1.0 + (la - ip))
            } else {
                2f64.powf(la)
            };
            out.set(bi, j, if sgn == 1 { -mag as f32 } else { mag as f32 });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::proptest::Rng;

    fn rand_case(rng: &mut Rng, b: usize, n: usize, d: usize) -> (Mat, Mat, Mat) {
        (
            Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        )
    }

    #[test]
    fn blocked_equals_unblocked_within_merge_error() {
        // Eq. 16 merging is itself approximate; outputs stay close
        let mut rng = Rng::new(31);
        let (q, k, v) = rand_case(&mut rng, 2, 64, 16);
        let a = attention(&q, &k, &v, None, None, &mut None);
        let b = attention_blocked(&q, &k, &v, 4, None, &mut None);
        let rel = b.rel_rms(&a);
        assert!(rel < 0.7, "blocked deviates too much: {rel}");
    }

    #[test]
    fn emu_all_on_tracks_int_path() {
        let mut rng = Rng::new(37);
        let (q, k, v) = rand_case(&mut rng, 2, 32, 8);
        let int_path = attention(&q, &k, &v, None, None, &mut None);
        let emu = attention_emu(&q, &k, &v, EmuConfig::all_on(), None);
        // emu carries f64 logs (no per-step requantization) so small
        // divergence is expected; they must agree to ~15%
        let rel = emu.rel_rms(&int_path);
        assert!(rel < 0.15, "emu vs int rel {rel}");
    }

    #[test]
    fn emu_all_off_matches_exact() {
        let mut rng = Rng::new(41);
        let (q, k, v) = rand_case(&mut rng, 2, 32, 8);
        let ex = exact::attention(&q, &k, &v, None, None);
        let emu = attention_emu(&q, &k, &v, EmuConfig::all_off(), None);
        let rel = emu.rel_rms(&ex);
        assert!(rel < 0.02, "all-off emu should be ~exact, rel {rel}");
    }

    #[test]
    fn mitchell_dominates_error_budget() {
        // the Table III headline: disabling Mitchell removes >80% of error
        let mut rng = Rng::new(43);
        let (q, k, v) = rand_case(&mut rng, 4, 64, 16);
        let ex = exact::attention(&q, &k, &v, None, None);
        let err_all = attention_emu(&q, &k, &v, EmuConfig::all_on(), None).rel_rms(&ex);
        let err_nomit = attention_emu(
            &q,
            &k,
            &v,
            EmuConfig { mitchell: false, ..EmuConfig::all_on() },
            None,
        )
        .rel_rms(&ex);
        assert!(err_nomit < 0.2 * err_all, "all {err_all}, no-mitchell {err_nomit}");
    }

    #[test]
    fn histogram_gets_filled() {
        let mut rng = Rng::new(47);
        let (q, k, v) = rand_case(&mut rng, 2, 16, 8);
        let mut h = MitchellHistogram::new(64);
        attention(&q, &k, &v, None, None, &mut Some(&mut h));
        assert!(h.total > 0);
        // paper Fig. 5: most inputs concentrate at small x
        assert!(h.mass_below(0.5) > 0.5);
    }

    #[test]
    fn zero_values_give_zero_output() {
        let q = Mat::from_vec(1, 4, vec![1.0, -0.5, 0.25, 0.0]);
        let k = Mat::from_vec(8, 4, vec![0.1; 32]);
        let v = Mat::zeros(8, 4);
        let o = attention(&q, &k, &v, None, None, &mut None);
        assert_eq!(o.data, vec![0.0; 4]);
    }

    #[test]
    fn finalize_into_matches_finalize() {
        let mut rng = Rng::new(53);
        let (q, k, v) = rand_case(&mut rng, 1, 12, 6);
        let states = partial_states(&q, &k, &v, None, None, &mut None);
        let by_vec = states[0].finalize();
        let mut by_slice = vec![7.0f32; 6]; // poisoned: every slot must be overwritten
        states[0].finalize_into(&mut by_slice);
        assert_eq!(
            by_vec.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            by_slice.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // empty-state rows finalize to zeros either way
        let empty = HfaState::new(6);
        let mut row = vec![1.0f32; 6];
        empty.finalize_into(&mut row);
        assert_eq!(row, vec![0.0; 6]);
    }

    #[test]
    #[should_panic(expected = "finalize_into width mismatch")]
    fn finalize_into_rejects_wrong_width() {
        let st = HfaState::new(4);
        let mut row = vec![0.0f32; 3];
        st.finalize_into(&mut row);
    }

    #[test]
    fn step_tile_slices_matches_per_query_steps() {
        // the tile variant IS the per-query loop: same states, same bits
        let mut rng = Rng::new(59);
        let (_, k, v) = rand_case(&mut rng, 1, 10, 4);
        let v_lns = prepared::convert_values(&v);
        let qt = 3;
        let mut tiled: Vec<HfaState> = (0..qt).map(|_| HfaState::new(4)).collect();
        let mut solo: Vec<HfaState> = (0..qt).map(|_| HfaState::new(4)).collect();
        for i in 0..k.rows {
            let scores: Vec<f32> = (0..qt).map(|t| (i as f32 - t as f32) * 0.37).collect();
            step_tile_slices(&mut tiled, &scores, v_lns.row_signs(i), v_lns.row_logs(i));
            for (t, st) in solo.iter_mut().enumerate() {
                st.step_slices(scores[t], v_lns.row_signs(i), v_lns.row_logs(i));
            }
        }
        for (a, b) in tiled.iter().zip(&solo) {
            assert_eq!(a.m.to_bits(), b.m.to_bits());
            assert_eq!(a.acc, b.acc);
        }
    }

    #[test]
    fn ell_lane_positive_and_growing() {
        // ell accumulates positive terms only -> sign 0 and log grows
        let mut st = HfaState::new(2);
        let v_lns = value_to_lns(&[0.5, -0.5], &mut None);
        let mut prev = i32::MIN;
        for i in 0..20 {
            st.step(i as f32 * 0.1, &v_lns, &mut None);
            let ell = st.acc.get(0);
            assert_eq!(ell.sign, 0);
            assert!(ell.log >= prev || ell.log >= 0, "ell shrank unexpectedly");
            prev = ell.log;
        }
    }
}

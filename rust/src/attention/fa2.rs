//! FlashAttention-2 streaming recurrence (paper Alg. 2) in f32 — the
//! all-floating-point baseline design ('FA-2') of the hardware evaluation.
//!
//! Single pass: per key, update the running max `m_i`, rescale the
//! exponential sum `l_i` and output `o_i` by `e^{m_{i-1}-m_i}`, accumulate
//! `e^{s_i-m_i}` terms, divide once at the end.

use crate::tensor::{dot_f32, Mat};

/// Partial FA-2 state for one query (the `(m, l, o)` triplet a block-FAU
/// hands to the ACC cascade in Fig. 2 — before the final division).
#[derive(Clone, Debug)]
pub struct Fa2State {
    pub m: f32,
    pub ell: f32,
    pub o: Vec<f32>,
}

impl Fa2State {
    pub fn new(dv: usize) -> Fa2State {
        Fa2State { m: f32::NEG_INFINITY, ell: 0.0, o: vec![0.0; dv] }
    }

    /// One inner-loop step of Alg. 2 (lines 3-6) given score `s` and value
    /// row `vrow`.
    #[inline]
    pub fn step(&mut self, s: f32, vrow: &[f32]) {
        let m_new = self.m.max(s);
        let alpha = if self.m == f32::NEG_INFINITY { 0.0 } else { (self.m - m_new).exp() };
        let beta = (s - m_new).exp();
        self.ell = self.ell * alpha + beta;
        for (o, &vv) in self.o.iter_mut().zip(vrow) {
            *o = *o * alpha + beta * vv;
        }
        self.m = m_new;
    }

    /// Final normalization (line 8).  A state that never stepped (every
    /// key masked) has `ell == 0` and a zero accumulator; 0/0 would be
    /// NaN, so the defined output is the zero row — matching the H-FA
    /// LogDiv, whose all-zero LNS lanes already finalize to zero.
    pub fn finalize(&self) -> Vec<f32> {
        if self.ell == 0.0 {
            return vec![0.0; self.o.len()];
        }
        self.o.iter().map(|&o| o / self.ell).collect()
    }
}

/// Alg. 2 over all queries.
pub fn attention(q: &Mat, k: &Mat, v: &Mat, scale: Option<f32>, mask: Option<&[bool]>) -> Mat {
    let states = partial_states(q, k, v, scale, mask);
    let mut out = Mat::zeros(q.rows, v.cols);
    for (bi, st) in states.iter().enumerate() {
        out.row_mut(bi).copy_from_slice(&st.finalize());
    }
    out
}

/// Run the inner loop only (no division) — one KV block's partial result.
pub fn partial_states(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    scale: Option<f32>,
    mask: Option<&[bool]>,
) -> Vec<Fa2State> {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    assert_eq!(k.cols, d);
    let scale = scale.unwrap_or(1.0 / (d as f32).sqrt());
    let mut states: Vec<Fa2State> = (0..b).map(|_| Fa2State::new(v.cols)).collect();
    for bi in 0..b {
        let qrow = q.row(bi);
        for i in 0..n {
            if mask.map(|m| !m[bi * n + i]).unwrap_or(false) {
                continue;
            }
            let s = dot_f32(qrow, k.row(i)) * scale;
            states[bi].step(s, v.row(i));
        }
    }
    states
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::proptest::{check, Rng};

    #[test]
    fn matches_exact_attention_property() {
        check(
            "fa2 == exact",
            23,
            25,
            |rng: &mut Rng| {
                let (b, n, d) = (1 + rng.below(3) as usize, 4 + rng.below(60) as usize, 16usize);
                (
                    Mat::from_vec(b, d, rng.normal_vec(b * d)),
                    Mat::from_vec(n, d, rng.normal_vec(n * d)),
                    Mat::from_vec(n, d, rng.normal_vec(n * d)),
                )
            },
            |(q, k, v)| {
                let diff = exact::attention(q, k, v, None, None)
                    .max_abs_diff(&attention(q, k, v, None, None));
                if diff < 1e-4 { Ok(()) } else { Err(format!("diff {diff}")) }
            },
        );
    }

    #[test]
    fn streaming_state_invariants() {
        // ell grows monotonically when max doesn't change; o stays finite
        let mut st = Fa2State::new(2);
        let mut prev_ell = 0.0;
        for i in 0..50 {
            st.step(-(i as f32) * 0.01, &[1.0, -1.0]);
            assert!(st.ell.is_finite() && st.ell >= prev_ell * 0.999);
            prev_ell = st.ell;
        }
        let o = st.finalize();
        assert!((o[0] - 1.0).abs() < 1e-6);
        assert!((o[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn descending_vs_ascending_scores_agree() {
        // the online rescaling must make result order-independent
        let v = Mat::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let k_asc = Mat::from_vec(4, 1, vec![0.1, 0.2, 0.3, 0.4]);
        let q = Mat::from_vec(1, 1, vec![5.0]);
        let o1 = attention(&q, &k_asc, &v, Some(1.0), None);
        // reversed key/value order
        let k_desc = Mat::from_vec(4, 1, vec![0.4, 0.3, 0.2, 0.1]);
        let v_rev = Mat::from_vec(4, 1, vec![4.0, 3.0, 2.0, 1.0]);
        let o2 = attention(&q, &k_desc, &v_rev, Some(1.0), None);
        assert!(o1.max_abs_diff(&o2) < 1e-5);
    }
}

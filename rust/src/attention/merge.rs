//! Merging partial attention results from parallel KV sub-blocks: the ACC
//! unit of Fig. 2/Fig. 4 — Eq. (1) in floating point for FA-2, Eq. (16)
//! in the log domain for H-FA.

use crate::arith::fix::quant_diff_q7;
use crate::arith::lns::lns_add_traced;
use crate::arith::mitchell::MitchellHistogram;

use super::fa2::Fa2State;
use super::hfa::HfaState;

/// FA-2 ACC (Eq. 1): floating-point rescale-and-add of two partial
/// `(m, l, o)` triplets.
pub fn merge_fa2(a: &Fa2State, b: &Fa2State) -> Fa2State {
    let m_n = a.m.max(b.m);
    let ea = if a.m == f32::NEG_INFINITY { 0.0 } else { (a.m - m_n).exp() };
    let eb = if b.m == f32::NEG_INFINITY { 0.0 } else { (b.m - m_n).exp() };
    Fa2State {
        m: m_n,
        ell: a.ell * ea + b.ell * eb,
        o: a.o.iter().zip(&b.o).map(|(&x, &y)| x * ea + y * eb).collect(),
    }
}

/// H-FA log-domain ACC (Eq. 16): quantized max-difference shifts + LNS
/// lane-wise addition.  Only the max comparison stays in floating point.
pub fn merge_hfa(
    a: &HfaState,
    b: &HfaState,
    hist: &mut Option<&mut MitchellHistogram>,
) -> HfaState {
    debug_assert_eq!(a.acc.len(), b.acc.len());
    let m_n = a.m.max(b.m);
    let da = quant_diff_q7(a.m - m_n);
    let db = quant_diff_q7(b.m - m_n);
    let mut out = HfaState::new(a.acc.len() - 1);
    out.m = m_n;
    for i in 0..a.acc.len() {
        let la = a.acc.get(i).scaled(da);
        let lb = b.acc.get(i).scaled(db);
        out.acc.set(i, lns_add_traced(la, lb, hist.as_deref_mut()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact, fa2, hfa};
    use crate::proptest::Rng;
    use crate::Mat;

    #[test]
    fn fa2_merge_equals_sequential() {
        // merging two half-block partials == streaming the full sequence
        let mut rng = Rng::new(3);
        let d = 8;
        let q = Mat::from_vec(1, d, rng.normal_vec(d));
        let k = Mat::from_vec(32, d, rng.normal_vec(32 * d));
        let v = Mat::from_vec(32, d, rng.normal_vec(32 * d));
        let full = fa2::attention(&q, &k, &v, None, None);

        let (ka, kb) = (k.rows_slice(0, 16), k.rows_slice(16, 32));
        let (va, vb) = (v.rows_slice(0, 16), v.rows_slice(16, 32));
        let sa = fa2::partial_states(&q, &ka, &va, None, None);
        let sb = fa2::partial_states(&q, &kb, &vb, None, None);
        let merged = merge_fa2(&sa[0], &sb[0]);
        let out = merged.finalize();
        for j in 0..d {
            assert!((out[j] - full.at(0, j)).abs() < 1e-5, "lane {j}");
        }
    }

    #[test]
    fn fa2_merge_commutative() {
        let mut rng = Rng::new(13);
        let d = 4;
        let q = Mat::from_vec(1, d, rng.normal_vec(d));
        let k = Mat::from_vec(16, d, rng.normal_vec(16 * d));
        let v = Mat::from_vec(16, d, rng.normal_vec(16 * d));
        let sa = fa2::partial_states(&q, &k.rows_slice(0, 8), &v.rows_slice(0, 8), None, None);
        let sb = fa2::partial_states(&q, &k.rows_slice(8, 16), &v.rows_slice(8, 16), None, None);
        let ab = merge_fa2(&sa[0], &sb[0]);
        let ba = merge_fa2(&sb[0], &sa[0]);
        assert!((ab.ell - ba.ell).abs() < 1e-5);
        for j in 0..d {
            assert!((ab.o[j] - ba.o[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn hfa_merge_close_to_exact_merge() {
        // log-domain merge approximates the float merge
        let mut rng = Rng::new(29);
        let d = 8;
        let q = Mat::from_vec(2, d, rng.normal_vec(2 * d)).round_bf16();
        let k = Mat::from_vec(32, d, rng.normal_vec(32 * d)).round_bf16();
        let v = Mat::from_vec(32, d, rng.normal_vec(32 * d)).round_bf16();
        let merged = hfa::attention_blocked(&q, &k, &v, 2, None, &mut None);
        let ex = exact::attention(&q, &k, &v, None, None);
        // same error regime as unblocked H-FA on mixed-sign values
        assert!(merged.rel_rms(&ex) < 1.0);
        assert_eq!(merged.rows, 2);
    }

    #[test]
    fn hfa_merge_of_two_empty_blocks_stays_empty() {
        // both operands never stepped (every key masked for this query,
        // in every block): m is -inf on both sides, so the quantizer sees
        // -inf - -inf = NaN — `quant_diff_q7` maps it to the clamp floor
        // and the zero lanes absorb the shift, so the merge chain of any
        // length stays the empty state and finalizes to a zero row
        // instead of NaN (the fully-masked grid edge, also pinned end to
        // end in `attention::tests::fully_masked_rows_return_zero_not_nan`)
        let empty = HfaState::new(4);
        let mut acc = HfaState::new(4);
        for _ in 0..3 {
            acc = merge_hfa(&acc, &empty, &mut None);
        }
        assert_eq!(acc.m, f32::NEG_INFINITY);
        assert_eq!(acc.acc, empty.acc, "zero lanes must survive the merge chain");
        assert_eq!(acc.finalize(), vec![0.0; 4]);
    }

    #[test]
    fn hfa_merge_with_empty_block_is_identity() {
        // a block that saw no keys (m = -inf, all lanes zero) must not
        // perturb the other operand
        let mut rng = Rng::new(57);
        let d = 4;
        let q = Mat::from_vec(1, d, rng.normal_vec(d)).round_bf16();
        let k = Mat::from_vec(8, d, rng.normal_vec(8 * d)).round_bf16();
        let v = Mat::from_vec(8, d, rng.normal_vec(8 * d)).round_bf16();
        let st = hfa::partial_states(&q, &k, &v, None, None, &mut None);
        let empty = hfa::HfaState::new(d);
        let merged = merge_hfa(&st[0], &empty, &mut None);
        assert_eq!(merged.acc, st[0].acc);
        assert_eq!(merged.m, st[0].m);
    }
}

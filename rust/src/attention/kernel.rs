//! Query-tiled, two-axis-parallel H-FA micro-kernel — the software
//! realization of the paper's Fig. 2 work partitioning, which exploits
//! **both** parallel axes of the accelerator: parallel queries (the
//! query-FAU rows) and parallel KV-block FAUs merged in the log domain
//! (Eq. 16).
//!
//! Two ideas, composed:
//!
//! * **Query tiling** ([`tile_states_prepared`] /
//!   [`tile_states_borrowed`]): instead of walking the whole KV plane
//!   once *per query* (the seed inner loop), a tile of up to
//!   [`MAX_QUERY_TILE`] query rows walks it together — each resident K
//!   row and V LNS lane pair is streamed **once per tile**, with the
//!   scores computed as a register-blocked `QT x 1` pass
//!   ([`super::hfa::step_tile_slices`]) before the shared lane planes
//!   are pushed through every accumulator.  Per-query accumulation
//!   order is untouched: every query still sees its keys in ascending
//!   row order through the same `dot_f32` / `step_slices` calls, so
//!   outputs are **bit-identical** to the seed per-row path (pinned by
//!   `rust/tests/tiled_kernel.rs`).  The memory-traffic win is counted
//!   exactly by [`kv_stream_bytes`] and pinned ~`QT`-fold by
//!   `rust/tests/kernel_traffic.rs`.
//!
//! * **Two-axis grid scheduling** ([`grid_states_prepared`] /
//!   [`grid_states_borrowed`]): the `(query-tile x KV-block)` grid fans
//!   out over the persistent worker pool as independent cells — the
//!   software analogue of Fig. 2's `p` block-FAUs times its parallel
//!   query rows.  A decode step (batch = 1) therefore parallelizes
//!   across its *resident KV blocks* instead of serializing on the
//!   single query.  Each query's per-block partials are then merged in
//!   block index order — the exact deterministic Eq. 16 chain the
//!   sequential block walk performed — so blocked outputs are also
//!   bit-identical whatever the grid shape.
//!
//! Masked calls hoist each query's mask row out of the inner loop (one
//! slice per tile row, not one closure evaluation per `(query, key)`).

// Always-std atomics (`counter`): `static` initializers need const `new`,
// which loom's types lack, and this is a monotonic traffic counter, not a
// synchronization protocol.
use crate::sync::counter::{AtomicU64, Ordering};

use crate::arith::lns::LnsMat;
use crate::runtime::pool::{fan_out, fan_out_chunked};
use crate::tensor::{dot_f32, Mat};

use super::hfa::{step_tile_slices, HfaState};
use super::merge::merge_hfa;
use super::prepared::{fixed_block_ranges, PreparedKv};

/// Default query-tile height `QT`: how many query rows share one stream
/// of the KV planes.  Eight keeps the score tile and the `QT` `(m, acc)`
/// states register/L1-resident at the paper's head dims (64-128) while
/// already amortizing the K/V stream 8x.
pub const DEFAULT_QUERY_TILE: usize = 8;

/// Hard cap on the tile height (the score tile is a fixed stack array).
pub const MAX_QUERY_TILE: usize = 16;

/// Minimum queries per pool job for the cheap post-grid merge pass —
/// one merge chain is `blocks x (d+1)` LNS adds, far too small to pay a
/// per-query job dispatch.
const MERGE_MIN_PER_JOB: usize = 32;

/// Process-wide count of KV plane bytes *streamed* by the micro-kernel:
/// each resident row a tile actually reads (any query attends to it)
/// charges its K floats plus both LNS lane planes exactly once for the
/// whole tile; rows masked out for every query in the tile charge
/// nothing.  The companion of `prepared::kv_copy_bytes` (write
/// traffic) — this one measures the read traffic the query-tiling
/// exists to amortize: unmasked per-query streaming (`qt = 1`) charges
/// `B x N` rows per call, a `QT`-tile charges `ceil(B/QT) x N`.
/// Pinned by `rust/tests/kernel_traffic.rs`.
static KV_STREAMED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total KV bytes streamed through the tiled kernel so far
/// (process-wide, all calls).
pub fn kv_stream_bytes() -> u64 {
    // ordering: Relaxed — monotonic counter read for reporting; no other
    // memory is published through it.
    KV_STREAMED_BYTES.load(Ordering::Relaxed)
}

/// Bytes one resident KV row costs to stream through the kernel: the K
/// floats plus the sign and log lane planes (`dv + 1` i32 each).  The V
/// float plane is not read by the H-FA inner loop (values are resident
/// in LNS form), so it is not charged.
pub fn row_stream_bytes(d: usize, dv: usize) -> u64 {
    (4 * d + 2 * 4 * (dv + 1)) as u64
}

#[inline]
fn record_stream(bytes: u64) {
    // ordering: Relaxed — counter increment only; totals are read after
    // the streaming calls return (program order suffices).
    KV_STREAMED_BYTES.fetch_add(bytes, Ordering::Relaxed);
}

#[inline]
fn clamp_tile(qt: usize) -> usize {
    qt.clamp(1, MAX_QUERY_TILE)
}

/// Hoisted per-query mask rows for one tile: `mask` is the full
/// `(B, span)` plane relative to the KV range; the returned slices are
/// one bounds-checked subslice per tile query instead of a closure
/// evaluation per `(query, key)`.
fn tile_mask_rows<'m>(
    mask: Option<&'m [bool]>,
    q_tile: (usize, usize),
    span: usize,
) -> Vec<&'m [bool]> {
    match mask {
        Some(m) => (q_tile.0..q_tile.1).map(|bi| &m[bi * span..(bi + 1) * span]).collect(),
        None => Vec::new(),
    }
}

/// One streamed KV row applied to a whole query tile: the
/// register-blocked score pass (all `QT` dots against the K row just
/// loaded), then one shared lane pass through every accumulator.  The
/// masked variant skips exactly the `(query, key)` pairs the seed path
/// skipped — masked queries pay neither the dot nor the lane update.
/// Returns whether the row was read at all (any query attended), so
/// the caller's [`kv_stream_bytes`] accounting stays exact under masks.
#[inline]
#[allow(clippy::too_many_arguments)] // flat hot-loop signature: every operand is a register-passed slice/scalar
fn tile_row_update(
    states: &mut [HfaState],
    qrows: &[&[f32]],
    tile_masks: &[&[bool]],
    i: usize,
    krow: &[f32],
    v_signs: &[i32],
    v_logs: &[i32],
    scale: f32,
    scores: &mut [f32; MAX_QUERY_TILE],
) -> bool {
    let qt = states.len();
    if tile_masks.is_empty() {
        for (sc, qrow) in scores[..qt].iter_mut().zip(qrows) {
            *sc = dot_f32(qrow, krow) * scale;
        }
        step_tile_slices(states, &scores[..qt], v_signs, v_logs);
        return true;
    }
    let mut touched = false;
    for (t, st) in states.iter_mut().enumerate() {
        if !tile_masks[t][i] {
            continue;
        }
        touched = true;
        st.step_slices(dot_f32(qrows[t], krow) * scale, v_signs, v_logs);
    }
    touched
}

/// The query-tiled micro-kernel over a **chunked** KV range: queries
/// `[q_tile.0, q_tile.1)` advance together past rows
/// `[range.0, range.1)`, resolving rows through the chunk table with the
/// chunk walk hoisted out of the inner loop (one lookup per crossed
/// boundary).  `mask`, when given, is the full `(B, span)` plane
/// relative to the range.  Bit-identical to running each query alone.
pub fn tile_states_prepared(
    kv: &PreparedKv,
    q: &Mat,
    q_tile: (usize, usize),
    range: (usize, usize),
    scale: f32,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    let (q_lo, q_hi) = q_tile;
    let (lo, hi) = range;
    let qt = q_hi - q_lo;
    debug_assert!(qt <= MAX_QUERY_TILE, "tile height {qt} over MAX_QUERY_TILE");
    debug_assert!(lo <= hi && hi <= kv.n(), "KV range out of bounds");
    let dv = kv.dv();
    let mut states: Vec<HfaState> = (0..qt).map(|_| HfaState::new(dv)).collect();
    if lo == hi || qt == 0 {
        return states;
    }
    let span = hi - lo;
    let qrows: Vec<&[f32]> = (q_lo..q_hi).map(|bi| q.row(bi)).collect();
    let tile_masks = tile_mask_rows(mask, q_tile, span);
    let mut scores = [0f32; MAX_QUERY_TILE];

    let br = kv.block_rows();
    let chunks = kv.chunks();
    let mut streamed_rows = 0u64;
    let mut r = lo;
    while r < hi {
        let ci = r / br;
        let chunk = chunks[ci].as_ref();
        let base = ci * br;
        let stop = hi.min(base + chunk.rows());
        for rr in r..stop {
            let o = rr - base;
            streamed_rows += tile_row_update(
                &mut states,
                &qrows,
                &tile_masks,
                rr - lo,
                chunk.k().row(o),
                chunk.v_lns().row_signs(o),
                chunk.v_lns().row_logs(o),
                scale,
                &mut scores,
            ) as u64;
        }
        r = stop;
    }
    record_stream(streamed_rows * row_stream_bytes(kv.d(), dv));
    states
}

/// [`tile_states_prepared`] over **dense** borrowed planes (the
/// golden-model paths that hold plain `Mat`/`LnsMat` operands).  Same
/// arithmetic, same streaming accounting.
pub fn tile_states_borrowed(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    q_tile: (usize, usize),
    range: (usize, usize),
    scale: f32,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    let (q_lo, q_hi) = q_tile;
    let (lo, hi) = range;
    let qt = q_hi - q_lo;
    debug_assert!(qt <= MAX_QUERY_TILE, "tile height {qt} over MAX_QUERY_TILE");
    debug_assert!(lo <= hi && hi <= k.rows && hi <= v_lns.rows(), "KV range out of bounds");
    let dv = v_lns.lanes() - 1;
    let mut states: Vec<HfaState> = (0..qt).map(|_| HfaState::new(dv)).collect();
    if lo == hi || qt == 0 {
        return states;
    }
    let span = hi - lo;
    let qrows: Vec<&[f32]> = (q_lo..q_hi).map(|bi| q.row(bi)).collect();
    let tile_masks = tile_mask_rows(mask, q_tile, span);
    let mut scores = [0f32; MAX_QUERY_TILE];
    let mut streamed_rows = 0u64;
    for i in 0..span {
        let r = lo + i;
        streamed_rows += tile_row_update(
            &mut states,
            &qrows,
            &tile_masks,
            i,
            k.row(r),
            v_lns.row_signs(r),
            v_lns.row_logs(r),
            scale,
            &mut scores,
        ) as u64;
    }
    record_stream(streamed_rows * row_stream_bytes(k.cols, dv));
    states
}

/// All of `q`'s rows over one KV range, tiled by `qt` and fanned out
/// over the persistent pool (one job per tile).  Tiles are contiguous
/// query ranges in index order, so the flattened result is in query
/// order — the drop-in pooled replacement for the seed's per-query
/// fan-out, with the K/V stream amortized `qt`-fold.
pub fn tiled_states_prepared(
    kv: &PreparedKv,
    q: &Mat,
    range: (usize, usize),
    scale: f32,
    mask: Option<&[bool]>,
    qt: usize,
) -> Vec<HfaState> {
    let tiles = fixed_block_ranges(q.rows, clamp_tile(qt));
    let per_tile = fan_out(tiles.len(), |ti| {
        tile_states_prepared(kv, q, tiles[ti], range, scale, mask)
    });
    per_tile.into_iter().flatten().collect()
}

/// Dense-plane counterpart of [`tiled_states_prepared`].
pub fn tiled_states_borrowed(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    range: (usize, usize),
    scale: f32,
    mask: Option<&[bool]>,
    qt: usize,
) -> Vec<HfaState> {
    let tiles = fixed_block_ranges(q.rows, clamp_tile(qt));
    let per_tile = fan_out(tiles.len(), |ti| {
        tile_states_borrowed(q, k, v_lns, tiles[ti], range, scale, mask)
    });
    per_tile.into_iter().flatten().collect()
}

/// Merge each query's per-block partial states in block index order —
/// the exact Eq. 16 chain `merge(merge(s_0, s_1), s_2)...` the
/// sequential block walk performed.  Fanned out in chunks because one
/// chain is far too little work for a per-query job (small batches run
/// inline on the submitting thread).  Cells are indexed as
/// `tile * nb + block` with uniform `qt`-high tiles (the
/// [`fixed_block_ranges`] partition the grids build).
fn merge_grid_cells(cells: &[Vec<HfaState>], nb: usize, b: usize, qt: usize) -> Vec<HfaState> {
    fan_out_chunked(b, MERGE_MIN_PER_JOB, |qi| {
        let (ti, t) = (qi / qt, qi % qt);
        let mut acc = cells[ti * nb][t].clone();
        for bj in 1..nb {
            acc = merge_hfa(&acc, &cells[ti * nb + bj][t], &mut None);
        }
        acc
    })
}

/// One session's slice of a fused cross-session dispatch: the prepared
/// KV set to attend over, its packed query rows, the KV-block partition
/// to grid over, and (optionally) a full `(q.rows, kv.n())` mask plane.
/// The scale is per-job because sessions in one dispatch may differ in
/// resident geometry.
pub struct GridJob<'a> {
    pub kv: &'a PreparedKv,
    pub q: &'a Mat,
    pub blocks: &'a [(usize, usize)],
    pub scale: f32,
    /// Optional `(q.rows, kv.n())` boolean plane (true = attend); each
    /// grid cell slices out its own block's mask rows.
    pub mask: Option<&'a [bool]>,
}

/// Ragged cross-session grid scheduler: every `(job x query-tile x
/// KV-block)` cell across **all** sessions is one independent pool job
/// fanned out in a single [`fan_out`] pass — the batch-level extension
/// of the two-axis grid (a worker dispatch spanning N one-query sessions
/// exposes `sum_j blocks_j` cells instead of serializing per session).
/// Each cell resolves rows through its own job's chunk table, so jobs
/// may differ in resident length, block partition and mask.  Per-query
/// merges then run in block index order within each job — the exact
/// Eq. 16 chain of the sequential walk — so every job's output is
/// bit-identical to scheduling that session alone (pinned by
/// `rust/tests/tiled_kernel.rs` and `rust/tests/fused_serving.rs`).
pub fn grid_states_multi(jobs: &[GridJob<'_>], qt: usize) -> Vec<Vec<HfaState>> {
    let qt = clamp_tile(qt);
    // flat cell descriptors `(job, tile range, block index)`, job-major /
    // tile-major / block-minor — the single-job layout is exactly the
    // pre-fusion grid's
    let mut cell_desc: Vec<(usize, (usize, usize), usize)> = Vec::new();
    let mut bases: Vec<usize> = Vec::with_capacity(jobs.len());
    for (ji, job) in jobs.iter().enumerate() {
        bases.push(cell_desc.len());
        if job.blocks.is_empty() || job.q.rows == 0 {
            continue;
        }
        for tile in fixed_block_ranges(job.q.rows, qt) {
            for bi in 0..job.blocks.len() {
                cell_desc.push((ji, tile, bi));
            }
        }
    }
    // hoisted per-(job, block) mask planes: the tile kernel wants each
    // block's columns as a range-relative (B, span) plane, and every
    // tile of a block reads the same plane — slice it once per block
    // here, not once per (tile x block) cell inside the fan-out
    let sub_masks: Vec<Vec<Vec<bool>>> = jobs
        .iter()
        .map(|job| {
            let Some(m) = job.mask else { return Vec::new() };
            let n = job.kv.n();
            job.blocks
                .iter()
                .map(|&(lo, hi)| {
                    let span = hi - lo;
                    let mut sub = Vec::with_capacity(job.q.rows * span);
                    for bi in 0..job.q.rows {
                        sub.extend_from_slice(&m[bi * n + lo..bi * n + hi]);
                    }
                    sub
                })
                .collect()
        })
        .collect();
    let cells: Vec<Vec<HfaState>> = fan_out(cell_desc.len(), |c| {
        let (ji, tile, bi) = cell_desc[c];
        let job = &jobs[ji];
        let mask = if job.mask.is_some() { Some(sub_masks[ji][bi].as_slice()) } else { None };
        tile_states_prepared(job.kv, job.q, tile, job.blocks[bi], job.scale, mask)
    });

    // per-query Eq. 16 merge chains for every multi-block job, fanned
    // out together (chunked — one chain is far too small for a job)
    let merge_list: Vec<(usize, usize)> = jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.blocks.len() > 1 && j.q.rows > 0)
        .flat_map(|(ji, j)| (0..j.q.rows).map(move |qi| (ji, qi)))
        .collect();
    let merged: Vec<HfaState> = fan_out_chunked(merge_list.len(), MERGE_MIN_PER_JOB, |i| {
        let (ji, qi) = merge_list[i];
        let nb = jobs[ji].blocks.len();
        let (ti, t) = (qi / qt, qi % qt);
        let base = bases[ji] + ti * nb;
        let mut acc = cells[base][t].clone();
        for bj in 1..nb {
            acc = merge_hfa(&acc, &cells[base + bj][t], &mut None);
        }
        acc
    });

    // assemble per-job outputs: merged chains for multi-block jobs,
    // flattened tile cells for single-block jobs, default (zero) states
    // for empty grids
    let mut cells = cells;
    let mut merged = merged.into_iter();
    let mut out: Vec<Vec<HfaState>> = Vec::with_capacity(jobs.len());
    for (ji, job) in jobs.iter().enumerate() {
        let b = job.q.rows;
        let nb = job.blocks.len();
        if nb == 0 || b == 0 {
            out.push((0..b).map(|_| HfaState::new(job.kv.dv())).collect());
        } else if nb == 1 {
            let tiles = b.div_ceil(qt);
            out.push(
                (0..tiles).flat_map(|ti| std::mem::take(&mut cells[bases[ji] + ti])).collect(),
            );
        } else {
            out.push(merged.by_ref().take(b).collect());
        }
    }
    out
}

/// Two-axis `(query-tile x KV-block)` grid over a chunked KV set: every
/// cell is one independent pool job, so a batch-1 decode step still
/// exposes `blocks.len()`-way parallelism (Fig. 2's two parallel axes),
/// then each query's partials merge in deterministic block order.
/// Bit-identical to the sequential block walk for every `qt` and block
/// partition (pinned by `rust/tests/tiled_kernel.rs`).  The single-job
/// case of [`grid_states_multi`].
pub fn grid_states_prepared(
    kv: &PreparedKv,
    q: &Mat,
    blocks: &[(usize, usize)],
    scale: f32,
    qt: usize,
) -> Vec<HfaState> {
    grid_states_multi(&[GridJob { kv, q, blocks, scale, mask: None }], qt)
        .pop()
        .expect("one job in, one state set out")
}

/// Dense-plane counterpart of [`grid_states_prepared`] — backs the
/// `hfa::attention_blocked` golden-model wrapper.
pub fn grid_states_borrowed(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    blocks: &[(usize, usize)],
    scale: f32,
    qt: usize,
) -> Vec<HfaState> {
    let b = q.rows;
    if blocks.is_empty() || b == 0 {
        return (0..b).map(|_| HfaState::new(v_lns.lanes() - 1)).collect();
    }
    let qt = clamp_tile(qt);
    let tiles = fixed_block_ranges(b, qt);
    let nb = blocks.len();
    let cells: Vec<Vec<HfaState>> = fan_out(tiles.len() * nb, |c| {
        tile_states_borrowed(q, k, v_lns, tiles[c / nb], blocks[c % nb], scale, None)
    });
    if nb == 1 {
        return cells.into_iter().flatten().collect();
    }
    merge_grid_cells(&cells, nb, b, qt)
}

#[cfg(test)]
mod tests {
    // NOTE: absolute kv_stream_bytes assertions live in
    // `rust/tests/kernel_traffic.rs` (sole test in its binary) — the
    // process-wide counter cannot be pinned here, where unit tests run
    // concurrently.  Bit-exactness sweeps live in
    // `rust/tests/tiled_kernel.rs`; these unit tests cover only the
    // kernel-local scaffolding.
    use super::*;
    use crate::proptest::Rng;

    #[test]
    fn clamp_tile_bounds() {
        assert_eq!(clamp_tile(0), 1);
        assert_eq!(clamp_tile(1), 1);
        assert_eq!(clamp_tile(MAX_QUERY_TILE), MAX_QUERY_TILE);
        assert_eq!(clamp_tile(MAX_QUERY_TILE + 100), MAX_QUERY_TILE);
        assert!(DEFAULT_QUERY_TILE <= MAX_QUERY_TILE);
    }

    #[test]
    fn row_stream_bytes_counts_k_and_lane_planes() {
        // d=64, dv=64: 64 K floats + 2 x 65 i32 lane entries
        assert_eq!(row_stream_bytes(64, 64), 4 * 64 + 2 * 4 * 65);
    }

    #[test]
    fn empty_grid_yields_default_states() {
        let mut rng = Rng::new(3);
        let k = Mat::from_vec(4, 4, rng.normal_vec(16)).round_bf16();
        let v = Mat::from_vec(4, 4, rng.normal_vec(16)).round_bf16();
        let kv = PreparedKv::new(k, v);
        let q = Mat::from_vec(2, 4, rng.normal_vec(8)).round_bf16();
        let st = grid_states_prepared(&kv, &q, &[], 0.5, 4);
        assert_eq!(st.len(), 2);
        for s in &st {
            assert_eq!(s.m, f32::NEG_INFINITY);
            assert_eq!(s.finalize(), vec![0.0; 4]);
        }
        // zero queries: empty state vector whatever the blocks
        let q0 = Mat::zeros(0, 4);
        assert!(grid_states_prepared(&kv, &q0, &[(0, 4)], 0.5, 4).is_empty());
    }

    #[test]
    fn multi_session_grid_bit_identical_to_solo_grids() {
        // a fused dispatch over sessions of different resident lengths,
        // block partitions and batch sizes must reproduce each session's
        // solo schedule bitwise — per-job merges never mix state
        let mut rng = Rng::new(17);
        let mk = |rng: &mut Rng, n: usize, d: usize, br: usize| {
            PreparedKv::with_block_rows(
                Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
                Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
                br,
            )
        };
        let kv_a = mk(&mut rng, 23, 4, 8);
        let kv_b = mk(&mut rng, 7, 4, 4);
        let kv_c = mk(&mut rng, 40, 4, 16);
        let q_a = Mat::from_vec(5, 4, rng.normal_vec(20)).round_bf16();
        let q_b = Mat::from_vec(1, 4, rng.normal_vec(4)).round_bf16();
        let q_c = Mat::from_vec(3, 4, rng.normal_vec(12)).round_bf16();
        let blocks_a = crate::attention::prepared::kv_block_ranges(23, 3);
        let blocks_b = crate::attention::prepared::kv_block_ranges(7, 1);
        let blocks_c = crate::attention::prepared::kv_block_ranges(40, 4);
        let jobs = [
            GridJob { kv: &kv_a, q: &q_a, blocks: &blocks_a, scale: 0.5, mask: None },
            GridJob { kv: &kv_b, q: &q_b, blocks: &blocks_b, scale: 0.25, mask: None },
            GridJob { kv: &kv_c, q: &q_c, blocks: &blocks_c, scale: 0.5, mask: None },
        ];
        for qt in [1usize, 2, 8] {
            let fused = grid_states_multi(&jobs, qt);
            assert_eq!(fused.len(), 3);
            for (ji, (job, got)) in jobs.iter().zip(&fused).enumerate() {
                let solo = grid_states_prepared(job.kv, job.q, job.blocks, job.scale, qt);
                assert_eq!(got.len(), solo.len(), "job {ji} qt={qt}");
                for (g, s) in got.iter().zip(&solo) {
                    assert_eq!(g.m.to_bits(), s.m.to_bits(), "job {ji} qt={qt}");
                    assert_eq!(g.acc, s.acc, "job {ji} qt={qt}");
                }
            }
        }
    }

    #[test]
    fn multi_grid_masked_job_matches_masked_tile_walk() {
        // a fused job carrying a (B, n) mask must slice per-block mask
        // columns exactly like the single-range masked tile path
        let mut rng = Rng::new(23);
        let n = 19;
        let k = Mat::from_vec(n, 4, rng.normal_vec(n * 4)).round_bf16();
        let v = Mat::from_vec(n, 4, rng.normal_vec(n * 4)).round_bf16();
        let kv = PreparedKv::with_block_rows(k, v, 8);
        let b = 3;
        let q = Mat::from_vec(b, 4, rng.normal_vec(b * 4)).round_bf16();
        let mask: Vec<bool> = (0..b * n).map(|i| i % 3 != 1).collect();
        let blocks = [(0usize, n)];
        let jobs =
            [GridJob { kv: &kv, q: &q, blocks: &blocks, scale: 0.5, mask: Some(&mask) }];
        let fused = grid_states_multi(&jobs, 2).pop().unwrap();
        let direct = tiled_states_prepared(&kv, &q, (0, n), 0.5, Some(&mask), 2);
        for (g, s) in fused.iter().zip(&direct) {
            assert_eq!(g.m.to_bits(), s.m.to_bits());
            assert_eq!(g.acc, s.acc);
        }
        // multi-block masked job: per-cell column slicing + block-order
        // merge must equal the hand-built per-block walk
        let two_blocks = [(0usize, 11usize), (11, n)];
        let jobs2 =
            [GridJob { kv: &kv, q: &q, blocks: &two_blocks, scale: 0.5, mask: Some(&mask) }];
        let fused2 = grid_states_multi(&jobs2, 8).pop().unwrap();
        for (bi, got) in fused2.iter().enumerate() {
            let mut want: Option<HfaState> = None;
            for &(lo, hi) in &two_blocks {
                let span = hi - lo;
                let mut sub = Vec::new();
                for row in 0..b {
                    sub.extend_from_slice(&mask[row * n + lo..row * n + hi]);
                }
                debug_assert_eq!(sub.len(), b * span);
                let st = tile_states_prepared(&kv, &q, (0, b), (lo, hi), 0.5, Some(&sub));
                want = Some(match want {
                    None => st[bi].clone(),
                    Some(prev) => merge_hfa(&prev, &st[bi], &mut None),
                });
            }
            let want = want.unwrap();
            assert_eq!(got.m.to_bits(), want.m.to_bits(), "query {bi}");
            assert_eq!(got.acc, want.acc, "query {bi}");
        }
    }

    #[test]
    fn tile_and_grid_agree_with_each_other() {
        // one-range grid == tiled walk of that range (no merge involved)
        let mut rng = Rng::new(9);
        let k = Mat::from_vec(10, 4, rng.normal_vec(40)).round_bf16();
        let v = Mat::from_vec(10, 4, rng.normal_vec(40)).round_bf16();
        let kv = PreparedKv::with_block_rows(k, v, 4);
        let q = Mat::from_vec(5, 4, rng.normal_vec(20)).round_bf16();
        let a = tiled_states_prepared(&kv, &q, (0, 10), 0.5, None, 2);
        let b = grid_states_prepared(&kv, &q, &[(0, 10)], 0.5, 2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.m.to_bits(), y.m.to_bits());
            assert_eq!(x.acc, y.acc);
        }
    }
}

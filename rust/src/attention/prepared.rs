//! Prepared-KV execution engine: the serving-path realization of the
//! paper's "KV sub-blocks preloaded into local buffers" assumption
//! (Section III-B).
//!
//! [`PreparedKv`] holds a session's KV as a table of fixed-capacity
//! **chunks** ([`KvChunk`]) — one chunk per [`fixed_block_ranges`] block:
//! K row-major plus V pre-converted *once* into SoA LNS lanes
//! ([`LnsMat`], `d+1` lanes per row including the prepended ell lane of
//! Eq. 12).  Every attention call against the session then runs pure
//! fixed-point adds over resident slices: no per-call linear->log
//! conversion, no per-row `LnsVec` allocation, and no copies for KV
//! sub-blocks — block boundaries are plain `(lo, hi)` row ranges
//! ([`KvBlockView`]).
//!
//! Chunks are shared via `Arc` across generations: cloning a
//! `PreparedKv` (the KV store's copy-on-write swap-in) clones only the
//! chunk *table* (one `Arc` pointer per resident chunk), and
//! [`PreparedKv::append`] copies at most the partially-filled tail chunk
//! before writing the new rows.  A T-token decode therefore performs
//! O(appended rows) bytes of copying per token — not O(resident rows),
//! which the previous monolithic-buffer layout paid on every
//! copy-on-write append (O(T^2) memcpy over a decode).  The traffic is
//! counted by the process-wide [`kv_copy_bytes`] counter and pinned by
//! `rust/tests/append_traffic.rs`.
//!
//! Query fan-out goes through the persistent [`crate::runtime::pool`]
//! worker pool instead of a per-call `std::thread::scope` spawn.
//!
//! Autoregressive decode grows a prepared set row-by-row with
//! [`PreparedKv::append`]: only the new V rows are converted, and the
//! capacity-driven chunk partition ([`fixed_block_ranges`]) keeps
//! earlier chunk boundaries fixed while the tail chunk fills — so
//! prefill+append is bit-identical to building from the full matrices
//! (pinned by `rust/tests/append_equivalence.rs`).
//!
//! Everything here is bit-identical to the serial seed path: the lane
//! update is the same `step_lanes_fast` kernel, conversions go through
//! `value_to_lns`, row values and iteration order are independent of the
//! chunk a row lands in, and per-query results are independent of the
//! thread that computed them (pinned by `rust/tests/prepared_exec.rs`
//! and the golden vectors in `rust/tests/golden_replay.rs`).

// Always-std atomics (`counter`): `static` initializers need const `new`,
// which loom's types lack, and this is a monotonic traffic counter, not a
// synchronization protocol.
use crate::sync::counter::{AtomicU64, Ordering};
use crate::sync::Arc;

use crate::arith::lns::LnsMat;
use crate::tensor::Mat;

use super::hfa::{finalize_states, value_to_lns, HfaState};
use super::kernel;

/// Process-wide count of bytes memcpy'd by prepared-KV builds, appends
/// and copy-on-write chunk clones (K + V float planes and LNS lane
/// planes; reads are free).  The companion of
/// `hfa::value_conversion_count`: the conversion counter pins *compute*
/// proportional to appended rows, this one pins *memory traffic*.
/// Pinned by `rust/tests/append_traffic.rs`.
static KV_COPIED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total prepared-KV bytes copied so far (process-wide, all sessions).
pub fn kv_copy_bytes() -> u64 {
    // ordering: Relaxed — monotonic counter read for reporting; no other
    // memory is published through it.
    KV_COPIED_BYTES.load(Ordering::Relaxed)
}

#[inline]
fn record_copy(bytes: usize) {
    // ordering: Relaxed — counter increment only; totals are read after
    // the traffic-generating calls return (program order suffices).
    KV_COPIED_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes one resident KV row occupies in prepared form: K floats, V
/// floats, and the `dv+1` LNS lanes (sign + log planes, i32 each).
/// This is the unit of the store's byte-budget accounting.
pub fn row_bytes(d: usize, dv: usize) -> usize {
    4 * d + 4 * dv + 2 * 4 * (dv + 1)
}

/// Convert a value matrix to its resident LNS lane form (`rows x (d+1)`,
/// lane 0 = LNS one).  One `value_to_lns` call per row — the only
/// linear->log conversion a session ever pays.
pub fn convert_values(v: &Mat) -> LnsMat {
    let lanes = v.cols + 1;
    let mut out = LnsMat::zeros(v.rows, lanes);
    for i in 0..v.rows {
        let row = value_to_lns(v.row(i), &mut None);
        out.set_row(i, &row);
    }
    out
}

/// Partition `n` key rows into at most `num_blocks` contiguous ranges.
/// Matches the seed's even split exactly when `num_blocks` divides `n`;
/// otherwise the last block carries the ragged tail (and blocks that
/// would start past `n` are dropped rather than panicking).
pub fn kv_block_ranges(n: usize, num_blocks: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let nb = num_blocks.max(1);
    let step = n.div_ceil(nb);
    (0..nb)
        .map(|b| (b * step, ((b + 1) * step).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

/// Sub-block capacity of the stored decode partition when none is given:
/// the paper's Section VI-C geometry (N=1024 over four 256-row blocks).
pub const DEFAULT_BLOCK_ROWS: usize = 256;

// FNV-1a 64 parameters for the chunk content hash.  FNV is only a
// *lookup key* for the KV store's prefix index — it is not collision
// resistant, and `put` is reachable by arbitrary wire clients, so dedup
// correctness must never rest on hash uniqueness.  It doesn't: before a
// resolved chunk is installed, [`PreparedKv::with_shared_chunks`]
// byte-compares its stored K/V planes against the source rows
// ([`KvChunk::matches_rows`]), so a collision — accidental, birthday-
// bound, or adversarially crafted — costs one wasted compare and a
// fresh build, never a wrong or cross-session chunk.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_u32(mut h: u64, w: u32) -> u64 {
    for b in w.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content hash of source rows `[lo, hi)` — the identity of the chunk
/// those rows would build.  Hashes the exact f32 bit patterns of the K
/// and V rows (callers hash the same BF16-rounded matrices they build
/// from) plus the row dims, so two sessions that `put` byte-identical
/// prefixes produce identical hashes and a dedup hit reuses a chunk
/// whose planes are bit-for-bit the ones a fresh build would write.
pub fn chunk_row_hash(k: &Mat, v: &Mat, lo: usize, hi: usize) -> u64 {
    assert_eq!(k.rows, v.rows, "K/V row count mismatch");
    assert!(lo <= hi && hi <= k.rows, "chunk hash range out of bounds");
    let mut h = FNV_OFFSET;
    h = fnv_u32(h, k.cols as u32);
    h = fnv_u32(h, v.cols as u32);
    h = fnv_u32(h, (hi - lo) as u32);
    for r in lo..hi {
        for &x in k.row(r) {
            h = fnv_u32(h, x.to_bits());
        }
        for &x in v.row(r) {
            h = fnv_u32(h, x.to_bits());
        }
    }
    h
}

/// Root link of a prefix-chain for the given chunk geometry.  The KV
/// store's radix index keys chunk *positions*, not bare contents: chunk
/// `i`'s key is `chain_link(link_{i-1}, hash_i)` starting from this
/// root, so a chunk only resolves when the entire prefix before it
/// matched too (and geometry mismatches can never alias).
pub fn chain_root(d: usize, dv: usize, block_rows: usize) -> u64 {
    let mut h = fnv_u32(FNV_OFFSET, 0x5052_4658); // "PRFX" domain tag
    h = fnv_u32(h, d as u32);
    h = fnv_u32(h, dv as u32);
    h = fnv_u32(h, block_rows as u32);
    h
}

/// Extend a prefix-chain link by one chunk hash (see [`chain_root`]).
pub fn chain_link(parent: u64, chunk_hash: u64) -> u64 {
    let mut h = parent;
    h = fnv_u32(h, (chunk_hash & 0xffff_ffff) as u32);
    h = fnv_u32(h, (chunk_hash >> 32) as u32);
    h
}

/// Partition `n` rows into fixed-capacity blocks of `block_rows` with a
/// ragged tail.  Unlike [`kv_block_ranges`] (count-driven, boundaries
/// move as `n` changes), this capacity-driven partition is append-stable:
/// growing `n` only widens the tail block until it fills, then opens new
/// blocks — earlier boundaries never move.  A pure function of
/// `(n, block_rows)`, which is what makes prefill+append bit-identical
/// to a from-scratch build.  The chunk table of a [`PreparedKv`] always
/// mirrors this partition exactly.
pub fn fixed_block_ranges(n: usize, block_rows: usize) -> Vec<(usize, usize)> {
    let br = block_rows.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(br));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + br).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// One fixed-capacity chunk of a prepared KV set — the software analogue
/// of one block-FAU's local SRAM buffer.  Holds up to `block_rows` rows
/// of K (row-major f32 holding BF16 values), V (same), and the
/// pre-converted LNS lanes.  Filled chunks are immutable and shared via
/// `Arc` across `PreparedKv` generations.
#[derive(Clone, Debug, PartialEq)]
pub struct KvChunk {
    k: Mat,
    v: Mat,
    v_lns: LnsMat,
}

impl KvChunk {
    /// An empty chunk preallocated for `rows_now` rows — the rows about
    /// to be written, **not** the full block capacity: a decode-opened
    /// chunk holds one row, a bulk-build chunk a whole block, so real
    /// allocation tracks residency and the store's byte accounting
    /// (which charges resident rows) stays honest.  Later tail growth
    /// is geometric (`Mat::append_row` / `LnsMat::push_row`), bounding
    /// uncharged allocator slack below 2x.
    fn with_capacity(rows_now: usize, d: usize, dv: usize) -> KvChunk {
        KvChunk {
            k: Mat::with_row_capacity(rows_now, d),
            v: Mat::with_row_capacity(rows_now, dv),
            v_lns: LnsMat::with_row_capacity(rows_now, dv + 1),
        }
    }

    /// Rows resident in this chunk.
    pub fn rows(&self) -> usize {
        self.k.rows
    }

    pub fn k(&self) -> &Mat {
        &self.k
    }

    pub fn v(&self) -> &Mat {
        &self.v
    }

    pub fn v_lns(&self) -> &LnsMat {
        &self.v_lns
    }

    /// Resident plane bytes of this chunk (K + V floats + LNS lanes).
    pub fn bytes(&self) -> usize {
        self.rows() * row_bytes(self.k.cols, self.v.cols)
    }

    /// Append rows `[lo, hi)` of the source matrices, converting the V
    /// rows to LNS.  Counts the written bytes against [`kv_copy_bytes`].
    fn push_rows(&mut self, k_src: &Mat, v_src: &Mat, lo: usize, hi: usize) {
        for r in lo..hi {
            self.k.append_row(k_src.row(r));
            self.v.append_row(v_src.row(r));
            let lrow = value_to_lns(v_src.row(r), &mut None);
            self.v_lns.push_row(&lrow);
        }
        record_copy((hi - lo) * row_bytes(self.k.cols, self.v.cols));
    }

    /// Bitwise equality of this chunk's stored K/V planes against source
    /// rows `[lo, hi)` — f32 bit patterns, so signed zeros and NaN
    /// payloads compare exactly.  The dedup install gate: a prefix-index
    /// hit is accepted only when this holds, so chunk reuse rests on the
    /// bytes themselves and the content hash stays a pure lookup key.
    /// Cheap next to the LNS conversion a hit skips (a memcmp-shaped
    /// scan of rows the hasher already streamed once).
    pub fn matches_rows(&self, k_src: &Mat, v_src: &Mat, lo: usize, hi: usize) -> bool {
        if self.rows() != hi - lo || self.k.cols != k_src.cols || self.v.cols != v_src.cols {
            return false;
        }
        (lo..hi).all(|r| {
            let o = r - lo;
            bits_eq(self.k.row(o), k_src.row(r)) && bits_eq(self.v.row(o), v_src.row(r))
        })
    }
}

#[inline]
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A session's KV prepared for repeated attention calls, stored as a
/// table of `Arc`-shared fixed-capacity chunks (chunk `i` covers rows
/// `[i*block_rows, ...)`; every chunk except the tail is full).  Grows
/// in place via [`PreparedKv::append`]; `Clone` copies only the chunk
/// table, never row data.
#[derive(Clone)]
pub struct PreparedKv {
    d: usize,
    dv: usize,
    /// Capacity of each stored sub-block (the block-FAU buffer size).
    block_rows: usize,
    /// Rows resident across all chunks.
    n: usize,
    chunks: Vec<Arc<KvChunk>>,
    /// Ragged `[lo, hi)` chunk ranges; always equals
    /// `fixed_block_ranges(n, block_rows)`.
    blocks: Vec<(usize, usize)>,
}

/// A zero-copy view of a contiguous KV sub-block (`[lo, hi)` rows) — the
/// software analogue of one block-FAU's local buffer.  Ranges may cross
/// chunk boundaries; row accessors resolve through the chunk table.
#[derive(Clone, Copy)]
pub struct KvBlockView<'a> {
    kv: &'a PreparedKv,
    lo: usize,
    hi: usize,
}

impl PreparedKv {
    /// Prepare owned K/V.  No rounding is applied here — callers decide
    /// the BF16 ingress convention (the KV store and accelerator round on
    /// load, mirroring the seed paths they replace).  The stored chunk
    /// partition uses [`DEFAULT_BLOCK_ROWS`].
    pub fn new(k: Mat, v: Mat) -> PreparedKv {
        PreparedKv::with_block_rows(k, v, DEFAULT_BLOCK_ROWS)
    }

    /// [`PreparedKv::with_block_rows`] with a prefix resolver: before
    /// each **full** (capacity-aligned) chunk is built, `resolve` is
    /// offered `(chunk index, content hash of its rows)` and may return
    /// an existing `Arc<KvChunk>` to install verbatim — those rows then
    /// pay zero copy bytes and zero `value_to_lns` conversions, and the
    /// attention grid streams the exact same planes every other holder
    /// streams (dedup is a storage choice, never a numeric one).  Every
    /// hit is verified before it is installed: the chunk's stored K/V
    /// planes must byte-match the source rows
    /// ([`KvChunk::matches_rows`]), so a stale or hash-colliding index
    /// entry can never substitute another session's data.  A `None` (or
    /// a hit whose geometry or bytes do not match) builds the chunk
    /// fresh, exactly like the unshared path; the ragged tail is always
    /// built fresh and privately owned.  This is the KV store's
    /// prefix-dedup ingest path: hashes are resolved against its radix
    /// index *before* any conversion work, so LNS conversion cost is
    /// proportional to unique rows fleet-wide, not sessions x rows.
    pub fn with_shared_chunks(
        k: &Mat,
        v: &Mat,
        block_rows: usize,
        mut resolve: impl FnMut(usize, u64) -> Option<Arc<KvChunk>>,
    ) -> PreparedKv {
        assert_eq!(k.rows, v.rows, "K/V row count mismatch");
        let block_rows = block_rows.max(1);
        let n = k.rows;
        let full = n / block_rows;
        let mut chunks = Vec::with_capacity(n.div_ceil(block_rows));
        for c in 0..full {
            let (lo, hi) = (c * block_rows, (c + 1) * block_rows);
            // matches_rows covers geometry (rows == block_rows via
            // hi - lo, both col dims) and the plane bytes themselves
            let hit =
                resolve(c, chunk_row_hash(k, v, lo, hi)).filter(|ch| ch.matches_rows(k, v, lo, hi));
            match hit {
                Some(ch) => chunks.push(ch),
                None => {
                    let mut fresh = KvChunk::with_capacity(block_rows, k.cols, v.cols);
                    fresh.push_rows(k, v, lo, hi);
                    chunks.push(Arc::new(fresh));
                }
            }
        }
        if n % block_rows != 0 {
            let lo = full * block_rows;
            let mut tail = KvChunk::with_capacity(n - lo, k.cols, v.cols);
            tail.push_rows(k, v, lo, n);
            chunks.push(Arc::new(tail));
        }
        PreparedKv {
            d: k.cols,
            dv: v.cols,
            block_rows,
            n,
            chunks,
            blocks: fixed_block_ranges(n, block_rows),
        }
    }

    /// [`PreparedKv::new`] with an explicit chunk capacity.
    pub fn with_block_rows(k: Mat, v: Mat, block_rows: usize) -> PreparedKv {
        assert_eq!(k.rows, v.rows, "K/V row count mismatch");
        let block_rows = block_rows.max(1);
        let mut kv = PreparedKv {
            d: k.cols,
            dv: v.cols,
            block_rows,
            n: 0,
            chunks: Vec::new(),
            blocks: Vec::new(),
        };
        kv.append(&k, &v);
        kv
    }

    /// Append decode-step K/V rows, converting **only** the new V rows
    /// into the resident LNS lanes — resident rows are never re-rounded
    /// or re-converted, and only the partially-filled tail chunk is ever
    /// copied (when shared), so per-step cost tracks the appended rows,
    /// not the sequence length.  The chunk table grows its tail chunk
    /// until it reaches `block_rows`, then opens new chunks — exactly
    /// the partition [`fixed_block_ranges`] computes from scratch, so
    /// prefill+append stays bit-identical to [`PreparedKv::new`] over
    /// the full matrices (pinned by `rust/tests/append_equivalence.rs`).
    ///
    /// No rounding is applied (same ingress convention as `new`).  When
    /// the tail chunk is `Arc`-shared it is copied on first write
    /// (`Arc::make_mut`, at most `block_rows` rows); filled chunks stay
    /// shared across generations and are never touched.
    pub fn append(&mut self, k_rows: &Mat, v_rows: &Mat) {
        assert_eq!(k_rows.cols, self.d, "K append dim mismatch");
        assert_eq!(v_rows.cols, self.dv, "V append dim mismatch");
        assert_eq!(k_rows.rows, v_rows.rows, "K/V append row count mismatch");
        if k_rows.rows == 0 {
            return;
        }
        let mut at = 0;
        while at < k_rows.rows {
            let tail_rows = self.chunks.last().map(|c| c.rows()).unwrap_or(self.block_rows);
            let open_new = tail_rows == self.block_rows;
            let cur_rows = if open_new { 0 } else { tail_rows };
            let take = (self.block_rows - cur_rows).min(k_rows.rows - at);
            if open_new {
                self.chunks.push(Arc::new(KvChunk::with_capacity(take, self.d, self.dv)));
            }
            let tail = self.chunks.last_mut().expect("tail chunk exists");
            if Arc::strong_count(tail) != 1 {
                // copy-on-write: the resident tail rows are about to be
                // cloned by make_mut — that memcpy is real traffic
                record_copy(tail.bytes());
            }
            Arc::make_mut(tail).push_rows(k_rows, v_rows, at, at + take);
            at += take;
            self.n += take;
        }
        // the capacity-driven partition is a pure function of (n, block
        // rows) — recomputing it *is* the tail-widen/open-new-chunks
        // update (earlier boundaries never move), at O(n/block_rows)
        // tuple writes, negligible next to the row writes above
        self.blocks = fixed_block_ranges(self.n, self.block_rows);
    }

    /// Copy-on-write [`PreparedKv::append`] for `Arc`-shared prepared KV
    /// (the KV store's swap-in path): the chunk table is cloned (one
    /// pointer per chunk), the tail chunk is copied, and only the new V
    /// rows pay a linear->log conversion.  Filled chunks are shared with
    /// `self`, so the copy cost is O(appended rows + block_rows), not
    /// O(resident rows).
    pub fn appended(&self, k_rows: &Mat, v_rows: &Mat) -> PreparedKv {
        let mut next = self.clone();
        next.append(k_rows, v_rows);
        next
    }

    /// Key/value rows resident.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Key (= query) dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Value dimension.
    pub fn dv(&self) -> usize {
        self.dv
    }

    /// Resident plane bytes across all chunks (chunk-granular sum; the
    /// unit the KV store's byte budget accounts in).  Charges resident
    /// rows; transient allocator slack from the tail chunk's geometric
    /// growth (< 2x of the tail, reset to exact on every copy-on-write
    /// clone) is not charged.
    pub fn resident_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.bytes()).sum()
    }

    /// [`PreparedKv::resident_bytes`] split into `(owned, shared)`:
    /// a chunk counts as *shared* when its `Arc` has other holders — a
    /// deduped sibling session, a forked ancestor/descendant, or an
    /// in-flight generation still streaming it — and *owned* when this
    /// table is the sole holder.  The two always sum to
    /// `resident_bytes()`.  This is a point-in-time observation (strong
    /// counts move as generations retire); the KV store's budget
    /// accounting uses its own refcount registry, not this split.
    pub fn partitioned_bytes(&self) -> (usize, usize) {
        let mut owned = 0;
        let mut shared = 0;
        for c in &self.chunks {
            if Arc::strong_count(c) > 1 {
                shared += c.bytes();
            } else {
                owned += c.bytes();
            }
        }
        (owned, shared)
    }

    /// Bytes of chunks this table holds exclusively (see
    /// [`PreparedKv::partitioned_bytes`]).
    pub fn owned_bytes(&self) -> usize {
        self.partitioned_bytes().0
    }

    /// Bytes of chunks shared with other holders (see
    /// [`PreparedKv::partitioned_bytes`]).
    pub fn shared_bytes(&self) -> usize {
        self.partitioned_bytes().1
    }

    /// The resident chunk table (chunk `i` covers stored block `i`).
    pub fn chunks(&self) -> &[Arc<KvChunk>] {
        &self.chunks
    }

    /// Chunk index and chunk-relative row of global row `r`.  Valid
    /// because every chunk except the tail holds exactly `block_rows`
    /// rows.
    #[inline]
    fn loc(&self, r: usize) -> (usize, usize) {
        debug_assert!(r < self.n);
        (r / self.block_rows, r % self.block_rows)
    }

    /// Key row `r` (zero-copy borrow from the owning chunk).
    #[inline]
    pub fn k_row(&self, r: usize) -> &[f32] {
        let (c, o) = self.loc(r);
        self.chunks[c].k.row(o)
    }

    /// Raw value row `r`.
    #[inline]
    pub fn v_row(&self, r: usize) -> &[f32] {
        let (c, o) = self.loc(r);
        self.chunks[c].v.row(o)
    }

    /// LNS sign lane plane of value row `r`.
    #[inline]
    pub fn v_row_signs(&self, r: usize) -> &[i32] {
        let (c, o) = self.loc(r);
        self.chunks[c].v_lns.row_signs(o)
    }

    /// LNS log lane plane of value row `r`.
    #[inline]
    pub fn v_row_logs(&self, r: usize) -> &[i32] {
        let (c, o) = self.loc(r);
        self.chunks[c].v_lns.row_logs(o)
    }

    /// Materialize key rows `[lo, hi)` into one contiguous matrix
    /// (O(hi-lo) copy — interop for dense-matrix consumers like the FA-2
    /// block path and static-shape PJRT kernels).
    pub fn k_rows(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.n, "k_rows range out of bounds");
        let mut out = Mat::with_row_capacity(hi - lo, self.d);
        for r in lo..hi {
            out.append_row(self.k_row(r));
        }
        out
    }

    /// Materialize value rows `[lo, hi)` (see [`PreparedKv::k_rows`]).
    pub fn v_rows(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.n, "v_rows range out of bounds");
        let mut out = Mat::with_row_capacity(hi - lo, self.dv);
        for r in lo..hi {
            out.append_row(self.v_row(r));
        }
        out
    }

    /// Materialize the whole K plane (O(n) copy).
    pub fn k_mat(&self) -> Mat {
        self.k_rows(0, self.n)
    }

    /// Materialize the whole V plane (O(n) copy).
    pub fn v_mat(&self) -> Mat {
        self.v_rows(0, self.n)
    }

    /// Materialize the resident LNS lanes as one contiguous [`LnsMat`]
    /// (O(n) copy of the *already converted* planes — no `value_to_lns`
    /// calls, so the conversion counter is untouched; test interop).
    pub fn v_lns_mat(&self) -> LnsMat {
        let mut out = LnsMat::with_row_capacity(self.n, self.dv + 1);
        for r in 0..self.n {
            out.push_row_slices(self.v_row_signs(r), self.v_row_logs(r));
        }
        out
    }

    /// Capacity of each stored sub-block.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// The stored append-stable ragged block partition (== the chunk
    /// table's row ranges).
    pub fn blocks(&self) -> &[(usize, usize)] {
        &self.blocks
    }

    /// 2D-parallel H-FA over the **stored** partition: one grid cell
    /// per `(query tile x resident chunk)`, log-domain ACC merge
    /// (Eq. 16) in block order, LogDiv.  Unlike
    /// [`PreparedKv::attention_blocked`] (count-driven boundaries that
    /// move as `n` grows), the stored boundaries are append-stable, so
    /// a step's merge tree does not shift under decode.  The serving
    /// stack currently drives the count-driven variant (the simulated
    /// accelerator has a fixed block-FAU count); this entry point is the
    /// building block for a stable-merge-tree decode schedule and is
    /// pinned by `rust/tests/append_equivalence.rs`.
    pub fn attention_resident_blocks(&self, q: &Mat, scale: Option<f32>) -> Mat {
        let scale = resolve_scale(scale, q.cols);
        let states =
            kernel::grid_states_prepared(self, q, &self.blocks, scale, kernel::DEFAULT_QUERY_TILE);
        finalize_states(&states, self.dv())
    }

    /// Zero-copy sub-block view of rows `[lo, hi)`.
    pub fn view(&self, lo: usize, hi: usize) -> KvBlockView<'_> {
        assert!(lo <= hi && hi <= self.n(), "view out of range");
        KvBlockView { kv: self, lo, hi }
    }

    /// Full-range view.
    pub fn full(&self) -> KvBlockView<'_> {
        self.view(0, self.n())
    }

    /// Bit-exact H-FA attention over the full resident KV.
    pub fn attention(&self, q: &Mat, scale: Option<f32>, mask: Option<&[bool]>) -> Mat {
        let states = self.full().partial_states(q, scale, mask);
        finalize_states(&states, self.dv())
    }

    /// 2D-parallel H-FA (Fig. 2) over the resident KV: the
    /// `(query tile x sub-block)` grid runs as independent pool jobs,
    /// log-domain ACC merge (Eq. 16) in block order, LogDiv.  The
    /// count-driven ranges need not align with chunk boundaries — rows
    /// resolve through the chunk table, in the same order and with the
    /// same values as the dense path, so results stay bit-identical.
    pub fn attention_blocked(&self, q: &Mat, num_blocks: usize, scale: Option<f32>) -> Mat {
        self.attention_tiled(q, num_blocks, scale, kernel::DEFAULT_QUERY_TILE)
    }

    /// [`PreparedKv::attention_blocked`] with an explicit query-tile
    /// height `qt` (clamped to `1..=`[`kernel::MAX_QUERY_TILE`]) — the
    /// benchable knob behind the kernel microbench and the tile sweep
    /// tests.  Outputs are bit-identical for every `qt`; only the K/V
    /// stream traffic and the grid's parallel shape change.
    pub fn attention_tiled(
        &self,
        q: &Mat,
        num_blocks: usize,
        scale: Option<f32>,
        qt: usize,
    ) -> Mat {
        let scale = resolve_scale(scale, q.cols);
        let ranges = kv_block_ranges(self.n, num_blocks);
        let states = kernel::grid_states_prepared(self, q, &ranges, scale, qt);
        finalize_states(&states, self.dv())
    }
}

/// Fused cross-session H-FA: one `(prepared KV, queries)` pair per
/// session, every session gridded over its own count-driven
/// `kv_block_ranges(n_i, num_blocks)` partition, **all** cells fanned
/// out through a single pool pass ([`kernel::grid_states_multi`]).
/// Per-query merges stay in block order within each session, so every
/// output matrix is bit-identical to calling
/// [`PreparedKv::attention_tiled`] on that session alone — fusion is a
/// scheduling choice, never a numeric one (pinned by
/// `rust/tests/fused_serving.rs`).
pub fn attention_multi(
    plan: &[(&PreparedKv, &Mat)],
    num_blocks: usize,
    scale: Option<f32>,
    qt: usize,
) -> Vec<Mat> {
    let ranges: Vec<Vec<(usize, usize)>> =
        plan.iter().map(|(kv, _)| kv_block_ranges(kv.n(), num_blocks)).collect();
    let jobs: Vec<kernel::GridJob<'_>> = plan
        .iter()
        .zip(&ranges)
        .map(|(&(kv, q), blocks)| kernel::GridJob {
            kv,
            q,
            blocks: blocks.as_slice(),
            scale: resolve_scale(scale, q.cols),
            mask: None,
        })
        .collect();
    kernel::grid_states_multi(&jobs, qt)
        .into_iter()
        .zip(plan)
        .map(|(states, (kv, _))| finalize_states(&states, kv.dv()))
        .collect()
}

impl<'a> KvBlockView<'a> {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    pub fn range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Key row `i` (view-relative).
    #[inline]
    pub fn k_row(&self, i: usize) -> &'a [f32] {
        self.kv.k_row(self.lo + i)
    }

    /// LNS value-row planes `i` (view-relative).
    #[inline]
    pub fn v_row_lns(&self, i: usize) -> (&'a [i32], &'a [i32]) {
        (self.kv.v_row_signs(self.lo + i), self.kv.v_row_logs(self.lo + i))
    }

    /// One KV block's partial `(m, sign, log)` triplet per query.  `mask`
    /// (when given) is `(B, len)` relative to this view, true = attend.
    pub fn partial_states(
        &self,
        q: &Mat,
        scale: Option<f32>,
        mask: Option<&[bool]>,
    ) -> Vec<HfaState> {
        partial_states_prepared(
            self.kv,
            q,
            self.lo,
            self.hi,
            resolve_scale(scale, q.cols),
            mask,
        )
    }
}

pub(crate) fn resolve_scale(scale: Option<f32>, d: usize) -> f32 {
    scale.unwrap_or(1.0 / (d as f32).sqrt())
}

/// The prepared-path inner engine over a chunked KV set: rows `[lo, hi)`
/// against resident LNS lanes, query-tiled and fanned out over the
/// persistent pool ([`kernel::tiled_states_prepared`] at the default
/// tile).  `mask` (when given) is `(B, hi - lo)` relative to the range.
///
/// The chunk walk is hoisted out of the inner loop (one chunk lookup per
/// crossed boundary, not per row) and each K row / V lane pair is
/// streamed once per query *tile*; row values and per-query accumulation
/// order are exactly the dense path's, so results are bit-identical to
/// [`partial_states_borrowed`] over the materialized planes — and to the
/// seed per-row path (`HfaState::step` with no histogram).
pub(crate) fn partial_states_prepared(
    kv: &PreparedKv,
    q: &Mat,
    lo: usize,
    hi: usize,
    scale: f32,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    assert_eq!(kv.d(), q.cols, "query dim mismatch");
    assert!(lo <= hi && hi <= kv.n(), "range out of bounds");
    if let Some(m) = mask {
        assert_eq!(m.len(), q.rows * (hi - lo), "mask shape mismatch");
    }
    kernel::tiled_states_prepared(kv, q, (lo, hi), scale, mask, kernel::DEFAULT_QUERY_TILE)
}

/// The dense-matrix inner engine (golden-model paths that hold plain
/// `Mat`/`LnsMat` operands): K rows `[lo, hi)` against converted lanes,
/// query-tiled over the persistent pool.  Same arithmetic as
/// [`partial_states_prepared`].
pub(crate) fn partial_states_borrowed(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    lo: usize,
    hi: usize,
    scale: f32,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    assert_eq!(k.cols, q.cols, "query dim mismatch");
    assert!(lo <= hi && hi <= k.rows && hi <= v_lns.rows(), "range out of bounds");
    if let Some(m) = mask {
        assert_eq!(m.len(), q.rows * (hi - lo), "mask shape mismatch");
    }
    kernel::tiled_states_borrowed(q, k, v_lns, (lo, hi), scale, mask, kernel::DEFAULT_QUERY_TILE)
}

/// Blocked partial-state computation + log-domain ACC merge over already
/// converted dense lanes — shared by the `hfa::attention_blocked`
/// golden-model wrapper.  Runs the same two-axis grid as the prepared
/// path ([`kernel::grid_states_borrowed`]), with the identical
/// in-block-order merge chain.
pub(crate) fn blocked_states(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    num_blocks: usize,
    scale: Option<f32>,
) -> Vec<HfaState> {
    let scale = resolve_scale(scale, q.cols);
    let ranges = kv_block_ranges(k.rows, num_blocks);
    kernel::grid_states_borrowed(q, k, v_lns, &ranges, scale, kernel::DEFAULT_QUERY_TILE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::lns::LnsVec;
    use crate::proptest::Rng;

    fn rand_kv(rng: &mut Rng, n: usize, d: usize) -> (Mat, Mat) {
        (
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        )
    }

    #[test]
    fn convert_values_matches_row_conversion() {
        let mut rng = Rng::new(3);
        let (_, v) = rand_kv(&mut rng, 9, 5);
        let m = convert_values(&v);
        assert_eq!((m.rows(), m.lanes()), (9, 6));
        for i in 0..9 {
            let expect: LnsVec = value_to_lns(v.row(i), &mut None);
            assert_eq!(m.row_vec(i), expect, "row {i}");
        }
    }

    #[test]
    fn block_ranges_even_split_matches_seed() {
        assert_eq!(kv_block_ranges(64, 4), vec![(0, 16), (16, 32), (32, 48), (48, 64)]);
        assert_eq!(kv_block_ranges(8, 1), vec![(0, 8)]);
    }

    #[test]
    fn block_ranges_ragged_and_degenerate() {
        assert_eq!(kv_block_ranges(10, 4), vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        // more blocks than rows: every row still covered exactly once
        let r = kv_block_ranges(3, 8);
        assert_eq!(r.iter().map(|(lo, hi)| hi - lo).sum::<usize>(), 3);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 3);
        assert!(kv_block_ranges(0, 4).is_empty());
        assert_eq!(kv_block_ranges(5, 0), vec![(0, 5)]);
    }

    #[test]
    fn view_rows_alias_prepared_storage() {
        let mut rng = Rng::new(7);
        let (k, v) = rand_kv(&mut rng, 16, 4);
        // block capacity 8: the view [4, 12) crosses a chunk boundary
        let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
        let view = kv.view(4, 12);
        assert_eq!(view.len(), 8);
        for i in 0..view.len() {
            assert_eq!(view.k_row(i), k.row(4 + i));
            let (vs, vl) = view.v_row_lns(i);
            let expect = value_to_lns(v.row(4 + i), &mut None);
            assert_eq!(vs, &expect.signs[..]);
            assert_eq!(vl, &expect.logs[..]);
        }
    }

    #[test]
    fn fixed_block_ranges_capacity_partition() {
        assert_eq!(fixed_block_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(fixed_block_ranges(3, 4), vec![(0, 3)]);
        assert_eq!(fixed_block_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(fixed_block_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        // degenerate capacity clamps to 1
        assert_eq!(fixed_block_ranges(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn chunk_table_mirrors_fixed_partition() {
        let mut rng = Rng::new(51);
        let (k, v) = rand_kv(&mut rng, 21, 4);
        let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
        assert_eq!(kv.chunks().len(), 3);
        assert_eq!(
            kv.chunks().iter().map(|c| c.rows()).collect::<Vec<_>>(),
            vec![8, 8, 5]
        );
        assert_eq!(kv.blocks(), fixed_block_ranges(21, 8));
        // row accessors agree with the source matrices across chunks
        for r in 0..21 {
            assert_eq!(kv.k_row(r), k.row(r), "k row {r}");
            assert_eq!(kv.v_row(r), v.row(r), "v row {r}");
        }
        assert_eq!(kv.k_mat().data, k.data);
        assert_eq!(kv.v_mat().data, v.data);
        assert_eq!(kv.v_lns_mat(), convert_values(&v));
        assert_eq!(kv.resident_bytes(), 21 * row_bytes(4, 4));
    }

    #[test]
    fn append_grows_tail_block_until_full() {
        let mut rng = Rng::new(19);
        let (k, v) = rand_kv(&mut rng, 3, 4);
        let mut kv = PreparedKv::with_block_rows(k, v, 4);
        assert_eq!(kv.blocks(), &[(0, 3)]);
        let (k2, v2) = rand_kv(&mut rng, 2, 4);
        kv.append(&k2, &v2); // 5 rows: tail fills to 4, new block opens
        assert_eq!(kv.blocks(), &[(0, 4), (4, 5)]);
        let (k3, v3) = rand_kv(&mut rng, 3, 4);
        kv.append(&k3, &v3); // 8 rows
        assert_eq!(kv.blocks(), &[(0, 4), (4, 8)]);
        let (k4, v4) = rand_kv(&mut rng, 1, 4);
        kv.append(&k4, &v4); // 9 rows
        assert_eq!(kv.blocks(), &[(0, 4), (4, 8), (8, 9)]);
        assert_eq!(kv.n(), 9);
        assert_eq!(kv.chunks().iter().map(|c| c.rows()).collect::<Vec<_>>(), vec![4, 4, 1]);
    }

    #[test]
    fn append_bit_identical_to_full_build() {
        let mut rng = Rng::new(23);
        let (k, v) = rand_kv(&mut rng, 21, 6);
        let full = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
        // prefill 4 rows, then ragged appends of 1/3/8/5 rows
        let mut grown = PreparedKv::with_block_rows(k.rows_slice(0, 4), v.rows_slice(0, 4), 8);
        let mut at = 4;
        for step in [1usize, 3, 8, 5] {
            grown.append(&k.rows_slice(at, at + step), &v.rows_slice(at, at + step));
            at += step;
        }
        assert_eq!(at, 21);
        assert_eq!(grown.n(), full.n());
        assert_eq!(grown.k_mat().data, full.k_mat().data);
        assert_eq!(grown.v_mat().data, full.v_mat().data);
        assert_eq!(grown.v_lns_mat(), full.v_lns_mat());
        assert_eq!(grown.blocks(), full.blocks());
        let q = Mat::from_vec(2, 6, rng.normal_vec(12)).round_bf16();
        assert_eq!(grown.attention(&q, None, None).data, full.attention(&q, None, None).data);
        assert_eq!(
            grown.attention_resident_blocks(&q, None).data,
            full.attention_resident_blocks(&q, None).data
        );
        assert_eq!(
            grown.attention_blocked(&q, 3, None).data,
            full.attention_blocked(&q, 3, None).data
        );
    }

    #[test]
    fn appended_leaves_the_shared_original_untouched() {
        let mut rng = Rng::new(29);
        let (k, v) = rand_kv(&mut rng, 6, 4);
        let base = Arc::new(PreparedKv::new(k.clone(), v.clone()));
        let (k2, v2) = rand_kv(&mut rng, 2, 4);
        let grown = base.appended(&k2, &v2);
        assert_eq!(base.n(), 6, "copy-on-write must not mutate the shared base");
        assert_eq!(grown.n(), 8);
        let gk = grown.k_mat();
        assert_eq!(&gk.data[..k.data.len()], &k.data[..]);
        assert_eq!(&gk.data[k.data.len()..], &k2.data[..]);
        assert_eq!(grown.v_lns_mat().row_vec(7), value_to_lns(v2.row(1), &mut None));
    }

    #[test]
    fn filled_chunks_are_shared_across_generations() {
        // the whole point of the chunk table: an append clones only the
        // tail chunk — every filled chunk is pointer-shared with the base
        let mut rng = Rng::new(37);
        let (k, v) = rand_kv(&mut rng, 10, 4);
        let base = PreparedKv::with_block_rows(k, v, 4); // chunks 4/4/2
        let (k1, v1) = rand_kv(&mut rng, 1, 4);
        let grown = base.appended(&k1, &v1); // chunks 4/4/3
        assert!(Arc::ptr_eq(&base.chunks()[0], &grown.chunks()[0]));
        assert!(Arc::ptr_eq(&base.chunks()[1], &grown.chunks()[1]));
        assert!(
            !Arc::ptr_eq(&base.chunks()[2], &grown.chunks()[2]),
            "the written tail chunk must have been copied, not mutated"
        );
        assert_eq!(base.chunks()[2].rows(), 2, "shared base tail untouched");
        assert_eq!(grown.chunks()[2].rows(), 3);
    }

    #[test]
    fn prepared_attention_matches_module_entrypoint() {
        let mut rng = Rng::new(11);
        let (k, v) = rand_kv(&mut rng, 32, 8);
        let q = Mat::from_vec(3, 8, rng.normal_vec(24)).round_bf16();
        let kv = PreparedKv::new(k.clone(), v.clone());
        let a = kv.attention(&q, None, None);
        let b = super::super::hfa::attention(&q, &k, &v, None, None, &mut None);
        assert_eq!(a.data, b.data);
        let ab = kv.attention_blocked(&q, 4, None);
        let bb = super::super::hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(ab.data, bb.data);
    }

    #[test]
    fn chunked_attention_bit_identical_across_chunkings() {
        // chunk capacity is a storage choice, not a numeric one: every
        // entry point must produce identical bits whatever the chunking,
        // including count-driven blocks that straddle chunk boundaries
        let mut rng = Rng::new(41);
        let (k, v) = rand_kv(&mut rng, 37, 8);
        let q = Mat::from_vec(4, 8, rng.normal_vec(32)).round_bf16();
        let reference = PreparedKv::with_block_rows(k.clone(), v.clone(), 37);
        let rf = reference.attention(&q, None, None).data;
        let rb = reference.attention_blocked(&q, 4, None).data;
        for br in [1usize, 3, 8, 16, 64] {
            let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), br);
            assert_eq!(kv.attention(&q, None, None).data, rf, "full, br={br}");
            assert_eq!(kv.attention_blocked(&q, 4, None).data, rb, "blocked, br={br}");
        }
    }

    #[test]
    fn attention_tiled_bit_identical_for_every_tile_height() {
        // the tile height is a scheduling knob, not a numeric one
        let mut rng = Rng::new(61);
        let (k, v) = rand_kv(&mut rng, 29, 8);
        let q = Mat::from_vec(7, 8, rng.normal_vec(56)).round_bf16();
        let kv = PreparedKv::with_block_rows(k, v, 8);
        let want = kv.attention_blocked(&q, 4, None).data;
        for qt in [1usize, 2, 3, 7, 16, 500] {
            assert_eq!(kv.attention_tiled(&q, 4, None, qt).data, want, "qt={qt}");
        }
    }

    #[test]
    fn chunk_row_hash_tracks_content_and_geometry() {
        let mut rng = Rng::new(67);
        let (k, v) = rand_kv(&mut rng, 16, 4);
        // deterministic, range-sensitive, content-sensitive
        assert_eq!(chunk_row_hash(&k, &v, 0, 8), chunk_row_hash(&k, &v, 0, 8));
        assert_ne!(chunk_row_hash(&k, &v, 0, 8), chunk_row_hash(&k, &v, 8, 16));
        let mut v2 = v.clone();
        v2.data[5] = (v2.data[5] + 1.0).max(1.0);
        assert_ne!(chunk_row_hash(&k, &v, 0, 8), chunk_row_hash(&k, &v2, 0, 8));
        // identical content at a different source offset hashes the same
        // (positional identity comes from the store's chain, not here)
        let mut kk = k.rows_slice(0, 8);
        let mut vv = v.rows_slice(0, 8);
        for r in 0..8 {
            kk.append_row(k.row(r));
            vv.append_row(v.row(r));
        }
        assert_eq!(chunk_row_hash(&kk, &vv, 8, 16), chunk_row_hash(&k, &v, 0, 8));
        // chain links separate position and geometry
        let root = chain_root(4, 4, 8);
        let h = chunk_row_hash(&k, &v, 0, 8);
        assert_ne!(chain_link(root, h), chain_link(chain_link(root, h), h));
        assert_ne!(chain_root(4, 4, 8), chain_root(4, 4, 16));
    }

    #[test]
    fn with_shared_chunks_reuses_hits_and_matches_fresh_build() {
        let mut rng = Rng::new(71);
        let (k, v) = rand_kv(&mut rng, 21, 4);
        let donor = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
        let mut offered = Vec::new();
        let shared = PreparedKv::with_shared_chunks(&k, &v, 8, |c, h| {
            offered.push((c, h));
            Some(Arc::clone(&donor.chunks()[c]))
        });
        // only the two full chunks are offered; the 5-row tail is private
        assert_eq!(offered.len(), 2);
        assert_eq!(offered[0].1, chunk_row_hash(&k, &v, 0, 8));
        assert!(Arc::ptr_eq(&shared.chunks()[0], &donor.chunks()[0]));
        assert!(Arc::ptr_eq(&shared.chunks()[1], &donor.chunks()[1]));
        assert!(!Arc::ptr_eq(&shared.chunks()[2], &donor.chunks()[2]));
        // bit-identical to the unshared build, blocks and planes alike
        assert_eq!(shared.blocks(), donor.blocks());
        assert_eq!(shared.k_mat().data, donor.k_mat().data);
        assert_eq!(shared.v_lns_mat(), donor.v_lns_mat());
        let q = Mat::from_vec(2, 4, rng.normal_vec(8)).round_bf16();
        assert_eq!(
            shared.attention(&q, None, None).data,
            donor.attention(&q, None, None).data
        );
        // resolver misses (and geometry-mismatched hits) build fresh
        let fresh = PreparedKv::with_shared_chunks(&k, &v, 8, |_, _| None);
        assert!(!Arc::ptr_eq(&fresh.chunks()[0], &donor.chunks()[0]));
        assert_eq!(fresh.v_lns_mat(), donor.v_lns_mat());
        let wrong = PreparedKv::with_block_rows(k.rows_slice(0, 4), v.rows_slice(0, 4), 4);
        let guarded = PreparedKv::with_shared_chunks(&k, &v, 8, |_, _| {
            Some(Arc::clone(&wrong.chunks()[0]))
        });
        assert_eq!(guarded.chunks()[0].rows(), 8, "bad-geometry hit must be rejected");
        assert_eq!(guarded.v_lns_mat(), donor.v_lns_mat());
    }

    #[test]
    fn content_mismatched_hits_are_rejected_and_built_fresh() {
        // a hash-colliding (or stale, or adversarially planted) index
        // entry has the right geometry but the wrong bytes: the install
        // gate must byte-verify and fall back to a fresh build, never
        // serve another session's planes
        let mut rng = Rng::new(79);
        let (k, v) = rand_kv(&mut rng, 16, 4);
        let (ko, vo) = rand_kv(&mut rng, 16, 4);
        let other = PreparedKv::with_block_rows(ko, vo, 8); // same geometry
        let built = PreparedKv::with_shared_chunks(&k, &v, 8, |c, _| {
            Some(Arc::clone(&other.chunks()[c]))
        });
        assert!(!Arc::ptr_eq(&built.chunks()[0], &other.chunks()[0]));
        assert!(!Arc::ptr_eq(&built.chunks()[1], &other.chunks()[1]));
        assert_eq!(built.k_mat().data, k.data, "wrong-content hit must not be installed");
        assert_eq!(built.v_lns_mat(), convert_values(&v));
        // matches_rows is exact: same chunk vs its own source rows holds,
        // a single flipped bit breaks it
        assert!(built.chunks()[0].matches_rows(&k, &v, 0, 8));
        let mut k2 = k.clone();
        k2.data[3] = f32::from_bits(k2.data[3].to_bits() ^ 1);
        assert!(!built.chunks()[0].matches_rows(&k2, &v, 0, 8));
        assert!(!built.chunks()[0].matches_rows(&k, &v, 8, 16), "offset rows differ");
    }

    #[test]
    fn partitioned_bytes_splits_owned_from_shared() {
        let mut rng = Rng::new(73);
        let (k, v) = rand_kv(&mut rng, 10, 4);
        let base = PreparedKv::with_block_rows(k, v, 4); // chunks 4/4/2
        let rb = row_bytes(4, 4);
        assert_eq!(base.partitioned_bytes(), (10 * rb, 0));
        let (k1, v1) = rand_kv(&mut rng, 1, 4);
        let grown = base.appended(&k1, &v1); // shares the two full chunks
        assert_eq!(grown.partitioned_bytes(), (3 * rb, 8 * rb));
        assert_eq!(base.partitioned_bytes(), (2 * rb, 8 * rb));
        assert_eq!(grown.owned_bytes() + grown.shared_bytes(), grown.resident_bytes());
        drop(base);
        assert_eq!(grown.partitioned_bytes(), (11 * rb, 0));
    }

    // NOTE: kv_copy_bytes assertions live in `rust/tests/append_traffic.rs`
    // (sole test in its binary) — the process-wide counter cannot be
    // asserted here, where unit tests run concurrently.
}

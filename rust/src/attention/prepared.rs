//! Prepared-KV execution engine: the serving-path realization of the
//! paper's "KV sub-blocks preloaded into local buffers" assumption
//! (Section III-B).
//!
//! [`PreparedKv`] holds a session's K row-major plus V pre-converted
//! *once* into SoA LNS lanes ([`LnsMat`], `d+1` lanes per row including
//! the prepended ell lane of Eq. 12).  Every attention call against the
//! session then runs pure fixed-point adds over resident slices: no
//! per-call linear->log conversion, no per-row `LnsVec` allocation, and
//! no `rows_slice` copies for KV sub-blocks — block boundaries are plain
//! `(lo, hi)` row ranges ([`KvBlockView`]).
//!
//! Query fan-out goes through the persistent [`crate::runtime::pool`]
//! worker pool instead of a per-call `std::thread::scope` spawn.
//!
//! Autoregressive decode grows a prepared set row-by-row with
//! [`PreparedKv::append`]: only the new V rows are converted, and the
//! stored capacity-driven block partition ([`fixed_block_ranges`]) keeps
//! earlier block boundaries fixed while its tail block fills — so
//! prefill+append is bit-identical to building from the full matrices
//! (pinned by `rust/tests/append_equivalence.rs`).
//!
//! Everything here is bit-identical to the serial seed path: the lane
//! update is the same `step_lanes_fast` kernel, conversions go through
//! `value_to_lns`, and per-query results are independent of the thread
//! that computed them (pinned by `rust/tests/prepared_exec.rs` and the
//! golden vectors in `rust/tests/golden_replay.rs`).

use std::sync::Arc;

use crate::arith::lns::LnsMat;
use crate::tensor::{dot_f32, Mat};

use super::hfa::{finalize_states, value_to_lns, HfaState};
use super::merge::merge_hfa;

/// Convert a value matrix to its resident LNS lane form (`rows x (d+1)`,
/// lane 0 = LNS one).  One `value_to_lns` call per row — the only
/// linear->log conversion a session ever pays.
pub fn convert_values(v: &Mat) -> LnsMat {
    let lanes = v.cols + 1;
    let mut out = LnsMat::zeros(v.rows, lanes);
    for i in 0..v.rows {
        let row = value_to_lns(v.row(i), &mut None);
        out.set_row(i, &row);
    }
    out
}

/// Partition `n` key rows into at most `num_blocks` contiguous ranges.
/// Matches the seed's even split exactly when `num_blocks` divides `n`;
/// otherwise the last block carries the ragged tail (and blocks that
/// would start past `n` are dropped rather than panicking).
pub fn kv_block_ranges(n: usize, num_blocks: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let nb = num_blocks.max(1);
    let step = n.div_ceil(nb);
    (0..nb)
        .map(|b| (b * step, ((b + 1) * step).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

/// Sub-block capacity of the stored decode partition when none is given:
/// the paper's Section VI-C geometry (N=1024 over four 256-row blocks).
pub const DEFAULT_BLOCK_ROWS: usize = 256;

/// Partition `n` rows into fixed-capacity blocks of `block_rows` with a
/// ragged tail.  Unlike [`kv_block_ranges`] (count-driven, boundaries
/// move as `n` changes), this capacity-driven partition is append-stable:
/// growing `n` only widens the tail block until it fills, then opens new
/// blocks — earlier boundaries never move.  A pure function of
/// `(n, block_rows)`, which is what makes prefill+append bit-identical
/// to a from-scratch build.
pub fn fixed_block_ranges(n: usize, block_rows: usize) -> Vec<(usize, usize)> {
    let br = block_rows.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(br));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + br).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// A session's KV prepared for repeated attention calls: K as given
/// (row-major f32 holding BF16 values) and V resident in the log domain,
/// plus the append-stable ragged block partition the decode path merges
/// over.  Grows in place via [`PreparedKv::append`].
#[derive(Clone)]
pub struct PreparedKv {
    k: Arc<Mat>,
    v: Arc<Mat>,
    v_lns: LnsMat,
    /// Capacity of each stored sub-block (the block-FAU buffer size).
    block_rows: usize,
    /// Ragged `[lo, hi)` block ranges; always equals
    /// `fixed_block_ranges(n, block_rows)`.
    blocks: Vec<(usize, usize)>,
}

/// A zero-copy view of a contiguous KV sub-block (`[lo, hi)` rows) — the
/// software analogue of one block-FAU's local buffer.
#[derive(Clone, Copy)]
pub struct KvBlockView<'a> {
    kv: &'a PreparedKv,
    lo: usize,
    hi: usize,
}

impl PreparedKv {
    /// Prepare owned K/V.  No rounding is applied here — callers decide
    /// the BF16 ingress convention (the KV store and accelerator round on
    /// load, mirroring the seed paths they replace).  The stored decode
    /// partition uses [`DEFAULT_BLOCK_ROWS`].
    pub fn new(k: Mat, v: Mat) -> PreparedKv {
        PreparedKv::from_arcs(Arc::new(k), Arc::new(v))
    }

    /// [`PreparedKv::new`] with an explicit stored sub-block capacity.
    pub fn with_block_rows(k: Mat, v: Mat, block_rows: usize) -> PreparedKv {
        PreparedKv::from_arcs_with_block_rows(Arc::new(k), Arc::new(v), block_rows)
    }

    /// Prepare shared K/V without copying the float matrices.
    pub fn from_arcs(k: Arc<Mat>, v: Arc<Mat>) -> PreparedKv {
        PreparedKv::from_arcs_with_block_rows(k, v, DEFAULT_BLOCK_ROWS)
    }

    /// [`PreparedKv::from_arcs`] with an explicit sub-block capacity.
    pub fn from_arcs_with_block_rows(
        k: Arc<Mat>,
        v: Arc<Mat>,
        block_rows: usize,
    ) -> PreparedKv {
        assert_eq!(k.rows, v.rows, "K/V row count mismatch");
        let v_lns = convert_values(v.as_ref());
        let block_rows = block_rows.max(1);
        let blocks = fixed_block_ranges(k.rows, block_rows);
        PreparedKv { k, v, v_lns, block_rows, blocks }
    }

    /// Append decode-step K/V rows, converting **only** the new V rows
    /// into the resident LNS lanes — resident rows are never re-rounded
    /// or re-converted, so per-step cost tracks the appended rows, not
    /// the sequence length.  The stored ragged partition grows its tail
    /// block until it reaches `block_rows`, then opens new blocks —
    /// exactly the partition [`fixed_block_ranges`] computes from
    /// scratch, so prefill+append stays bit-identical to
    /// [`PreparedKv::new`] over the full matrices (pinned by
    /// `rust/tests/append_equivalence.rs`).
    ///
    /// No rounding is applied (same ingress convention as `new`).  When
    /// the float matrices are `Arc`-shared they are copied on first
    /// write (`Arc::make_mut`); a uniquely-owned cache grows truly in
    /// place.
    pub fn append(&mut self, k_rows: &Mat, v_rows: &Mat) {
        assert_eq!(k_rows.cols, self.k.cols, "K append dim mismatch");
        assert_eq!(v_rows.cols, self.v.cols, "V append dim mismatch");
        assert_eq!(k_rows.rows, v_rows.rows, "K/V append row count mismatch");
        if k_rows.rows == 0 {
            return;
        }
        Arc::make_mut(&mut self.k).append_rows(k_rows);
        Arc::make_mut(&mut self.v).append_rows(v_rows);
        for i in 0..v_rows.rows {
            let row = value_to_lns(v_rows.row(i), &mut None);
            self.v_lns.push_row(&row);
        }
        // the capacity-driven partition is a pure function of (n, block
        // rows) — recomputing it *is* the tail-widen/open-new-blocks
        // update (earlier boundaries never move), at O(n/block_rows)
        // tuple writes, negligible next to the row copies above
        self.blocks = fixed_block_ranges(self.k.rows, self.block_rows);
    }

    /// Copy-on-write [`PreparedKv::append`] for `Arc`-shared prepared KV
    /// (the KV store's swap-in path): resident float/LNS planes are
    /// memcpy'd, only the new V rows pay a linear->log conversion.
    pub fn appended(&self, k_rows: &Mat, v_rows: &Mat) -> PreparedKv {
        let mut next = self.clone();
        next.append(k_rows, v_rows);
        next
    }

    /// Key/value rows resident.
    pub fn n(&self) -> usize {
        self.k.rows
    }

    /// Key (= query) dimension.
    pub fn d(&self) -> usize {
        self.k.cols
    }

    /// Value dimension.
    pub fn dv(&self) -> usize {
        self.v.cols
    }

    pub fn k(&self) -> &Mat {
        &self.k
    }

    pub fn v(&self) -> &Mat {
        &self.v
    }

    pub fn k_arc(&self) -> Arc<Mat> {
        self.k.clone()
    }

    pub fn v_arc(&self) -> Arc<Mat> {
        self.v.clone()
    }

    pub fn v_lns(&self) -> &LnsMat {
        &self.v_lns
    }

    /// Capacity of each stored sub-block.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// The stored append-stable ragged block partition.
    pub fn blocks(&self) -> &[(usize, usize)] {
        &self.blocks
    }

    /// 2D-parallel H-FA over the **stored** partition: one partial FAU
    /// per resident sub-block, log-domain ACC merge (Eq. 16), LogDiv.
    /// Unlike [`PreparedKv::attention_blocked`] (count-driven boundaries
    /// that move as `n` grows), the stored boundaries are append-stable,
    /// so a step's merge tree does not shift under decode.  The serving
    /// stack currently drives the count-driven variant (the simulated
    /// accelerator has a fixed block-FAU count); this entry point is the
    /// building block for a stable-merge-tree decode schedule and is
    /// pinned by `rust/tests/append_equivalence.rs`.
    pub fn attention_resident_blocks(&self, q: &Mat, scale: Option<f32>) -> Mat {
        let scale = resolve_scale(scale, q.cols);
        let dv = self.dv();
        let mut acc: Option<Vec<HfaState>> = None;
        for &(lo, hi) in &self.blocks {
            let st = partial_states_borrowed(q, &self.k, &self.v_lns, lo, hi, scale, None);
            acc = Some(match acc {
                None => st,
                Some(prev) => prev
                    .into_iter()
                    .zip(st)
                    .map(|(a, b)| merge_hfa(&a, &b, &mut None))
                    .collect(),
            });
        }
        let states = acc.unwrap_or_else(|| (0..q.rows).map(|_| HfaState::new(dv)).collect());
        finalize_states(&states, dv)
    }

    /// Zero-copy sub-block view of rows `[lo, hi)`.
    pub fn view(&self, lo: usize, hi: usize) -> KvBlockView<'_> {
        assert!(lo <= hi && hi <= self.n(), "view out of range");
        KvBlockView { kv: self, lo, hi }
    }

    /// Full-range view.
    pub fn full(&self) -> KvBlockView<'_> {
        self.view(0, self.n())
    }

    /// Bit-exact H-FA attention over the full resident KV.
    pub fn attention(&self, q: &Mat, scale: Option<f32>, mask: Option<&[bool]>) -> Mat {
        let states = self.full().partial_states(q, scale, mask);
        finalize_states(&states, self.dv())
    }

    /// 2D-parallel H-FA (Fig. 2) over the resident KV: independent
    /// partial FAUs per sub-block, log-domain ACC merge (Eq. 16), LogDiv.
    pub fn attention_blocked(&self, q: &Mat, num_blocks: usize, scale: Option<f32>) -> Mat {
        let states = blocked_states(q, &self.k, &self.v_lns, num_blocks, scale);
        finalize_states(&states, self.dv())
    }
}

impl<'a> KvBlockView<'a> {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    pub fn range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Key row `i` (view-relative).
    #[inline]
    pub fn k_row(&self, i: usize) -> &'a [f32] {
        self.kv.k.row(self.lo + i)
    }

    /// LNS value-row planes `i` (view-relative).
    #[inline]
    pub fn v_row_lns(&self, i: usize) -> (&'a [i32], &'a [i32]) {
        (
            self.kv.v_lns.row_signs(self.lo + i),
            self.kv.v_lns.row_logs(self.lo + i),
        )
    }

    /// One KV block's partial `(m, sign, log)` triplet per query.  `mask`
    /// (when given) is `(B, len)` relative to this view, true = attend.
    pub fn partial_states(
        &self,
        q: &Mat,
        scale: Option<f32>,
        mask: Option<&[bool]>,
    ) -> Vec<HfaState> {
        partial_states_borrowed(
            q,
            self.kv.k(),
            self.kv.v_lns(),
            self.lo,
            self.hi,
            resolve_scale(scale, q.cols),
            mask,
        )
    }
}

pub(crate) fn resolve_scale(scale: Option<f32>, d: usize) -> f32 {
    scale.unwrap_or(1.0 / (d as f32).sqrt())
}

/// The prepared-path inner engine over borrowed parts: K rows `[lo, hi)`
/// against resident LNS lanes, fanned out over the persistent pool.
/// `mask` (when given) is `(B, hi - lo)` relative to the range.
///
/// Every query is an independent FAU, so results are identical to serial
/// execution regardless of thread assignment — and bit-identical to the
/// seed per-row path (`HfaState::step` with no histogram).
pub(crate) fn partial_states_borrowed(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    lo: usize,
    hi: usize,
    scale: f32,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    assert_eq!(k.cols, q.cols, "query dim mismatch");
    assert!(lo <= hi && hi <= k.rows && hi <= v_lns.rows(), "range out of bounds");
    let b = q.rows;
    let span = hi - lo;
    let dv = v_lns.lanes() - 1;
    if let Some(m) = mask {
        assert_eq!(m.len(), b * span, "mask shape mismatch");
    }

    let run_query = |bi: usize| -> HfaState {
        let mut st = HfaState::new(dv);
        let qrow = q.row(bi);
        for i in 0..span {
            if mask.map(|m| !m[bi * span + i]).unwrap_or(false) {
                continue;
            }
            let s = dot_f32(qrow, k.row(lo + i)) * scale;
            st.step_slices(s, v_lns.row_signs(lo + i), v_lns.row_logs(lo + i));
        }
        st
    };
    crate::runtime::pool::fan_out(b, run_query)
}

/// Blocked partial-state computation + log-domain ACC merge over already
/// converted lanes — shared by [`PreparedKv::attention_blocked`] and the
/// `hfa::attention_blocked` wrapper.
pub(crate) fn blocked_states(
    q: &Mat,
    k: &Mat,
    v_lns: &LnsMat,
    num_blocks: usize,
    scale: Option<f32>,
) -> Vec<HfaState> {
    let scale = resolve_scale(scale, q.cols);
    let dv = v_lns.lanes() - 1;
    let mut acc: Option<Vec<HfaState>> = None;
    for (lo, hi) in kv_block_ranges(k.rows, num_blocks) {
        let st = partial_states_borrowed(q, k, v_lns, lo, hi, scale, None);
        acc = Some(match acc {
            None => st,
            Some(prev) => prev
                .into_iter()
                .zip(st)
                .map(|(a, b)| merge_hfa(&a, &b, &mut None))
                .collect(),
        });
    }
    acc.unwrap_or_else(|| (0..q.rows).map(|_| HfaState::new(dv)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::lns::LnsVec;
    use crate::proptest::Rng;

    fn rand_kv(rng: &mut Rng, n: usize, d: usize) -> (Mat, Mat) {
        (
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
            Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        )
    }

    #[test]
    fn convert_values_matches_row_conversion() {
        let mut rng = Rng::new(3);
        let (_, v) = rand_kv(&mut rng, 9, 5);
        let m = convert_values(&v);
        assert_eq!((m.rows(), m.lanes()), (9, 6));
        for i in 0..9 {
            let expect: LnsVec = value_to_lns(v.row(i), &mut None);
            assert_eq!(m.row_vec(i), expect, "row {i}");
        }
    }

    #[test]
    fn block_ranges_even_split_matches_seed() {
        assert_eq!(kv_block_ranges(64, 4), vec![(0, 16), (16, 32), (32, 48), (48, 64)]);
        assert_eq!(kv_block_ranges(8, 1), vec![(0, 8)]);
    }

    #[test]
    fn block_ranges_ragged_and_degenerate() {
        assert_eq!(kv_block_ranges(10, 4), vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        // more blocks than rows: every row still covered exactly once
        let r = kv_block_ranges(3, 8);
        assert_eq!(r.iter().map(|(lo, hi)| hi - lo).sum::<usize>(), 3);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 3);
        assert!(kv_block_ranges(0, 4).is_empty());
        assert_eq!(kv_block_ranges(5, 0), vec![(0, 5)]);
    }

    #[test]
    fn view_rows_alias_prepared_storage() {
        let mut rng = Rng::new(7);
        let (k, v) = rand_kv(&mut rng, 16, 4);
        let kv = PreparedKv::new(k.clone(), v.clone());
        let view = kv.view(4, 12);
        assert_eq!(view.len(), 8);
        for i in 0..view.len() {
            assert_eq!(view.k_row(i), k.row(4 + i));
            let (vs, vl) = view.v_row_lns(i);
            let expect = value_to_lns(v.row(4 + i), &mut None);
            assert_eq!(vs, &expect.signs[..]);
            assert_eq!(vl, &expect.logs[..]);
        }
    }

    #[test]
    fn fixed_block_ranges_capacity_partition() {
        assert_eq!(fixed_block_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(fixed_block_ranges(3, 4), vec![(0, 3)]);
        assert_eq!(fixed_block_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(fixed_block_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        // degenerate capacity clamps to 1
        assert_eq!(fixed_block_ranges(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn append_grows_tail_block_until_full() {
        let mut rng = Rng::new(19);
        let (k, v) = rand_kv(&mut rng, 3, 4);
        let mut kv = PreparedKv::with_block_rows(k, v, 4);
        assert_eq!(kv.blocks(), &[(0, 3)]);
        let (k2, v2) = rand_kv(&mut rng, 2, 4);
        kv.append(&k2, &v2); // 5 rows: tail fills to 4, new block opens
        assert_eq!(kv.blocks(), &[(0, 4), (4, 5)]);
        let (k3, v3) = rand_kv(&mut rng, 3, 4);
        kv.append(&k3, &v3); // 8 rows
        assert_eq!(kv.blocks(), &[(0, 4), (4, 8)]);
        let (k4, v4) = rand_kv(&mut rng, 1, 4);
        kv.append(&k4, &v4); // 9 rows
        assert_eq!(kv.blocks(), &[(0, 4), (4, 8), (8, 9)]);
        assert_eq!(kv.n(), 9);
    }

    #[test]
    fn append_bit_identical_to_full_build() {
        let mut rng = Rng::new(23);
        let (k, v) = rand_kv(&mut rng, 21, 6);
        let full = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
        // prefill 4 rows, then ragged appends of 1/3/8/5 rows
        let mut grown = PreparedKv::with_block_rows(k.rows_slice(0, 4), v.rows_slice(0, 4), 8);
        let mut at = 4;
        for step in [1usize, 3, 8, 5] {
            grown.append(&k.rows_slice(at, at + step), &v.rows_slice(at, at + step));
            at += step;
        }
        assert_eq!(at, 21);
        assert_eq!(grown.n(), full.n());
        assert_eq!(grown.k().data, full.k().data);
        assert_eq!(grown.v().data, full.v().data);
        assert_eq!(grown.v_lns(), full.v_lns());
        assert_eq!(grown.blocks(), full.blocks());
        let q = Mat::from_vec(2, 6, rng.normal_vec(12)).round_bf16();
        assert_eq!(grown.attention(&q, None, None).data, full.attention(&q, None, None).data);
        assert_eq!(
            grown.attention_resident_blocks(&q, None).data,
            full.attention_resident_blocks(&q, None).data
        );
        assert_eq!(
            grown.attention_blocked(&q, 3, None).data,
            full.attention_blocked(&q, 3, None).data
        );
    }

    #[test]
    fn appended_leaves_the_shared_original_untouched() {
        let mut rng = Rng::new(29);
        let (k, v) = rand_kv(&mut rng, 6, 4);
        let base = Arc::new(PreparedKv::new(k.clone(), v.clone()));
        let (k2, v2) = rand_kv(&mut rng, 2, 4);
        let grown = base.appended(&k2, &v2);
        assert_eq!(base.n(), 6, "copy-on-write must not mutate the shared base");
        assert_eq!(grown.n(), 8);
        assert_eq!(&grown.k().data[..k.data.len()], &k.data[..]);
        assert_eq!(&grown.k().data[k.data.len()..], &k2.data[..]);
        assert_eq!(grown.v_lns().row_vec(7), value_to_lns(v2.row(1), &mut None));
    }

    #[test]
    fn prepared_attention_matches_module_entrypoint() {
        let mut rng = Rng::new(11);
        let (k, v) = rand_kv(&mut rng, 32, 8);
        let q = Mat::from_vec(3, 8, rng.normal_vec(24)).round_bf16();
        let kv = PreparedKv::new(k.clone(), v.clone());
        let a = kv.attention(&q, None, None);
        let b = super::super::hfa::attention(&q, &k, &v, None, None, &mut None);
        assert_eq!(a.data, b.data);
        let ab = kv.attention_blocked(&q, 4, None);
        let bb = super::super::hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(ab.data, bb.data);
    }
}

//! Synthetic LLM benchmark suite — the Table I/II/III accuracy study
//! (substitute for MMLU/GPQA/SWAG/GSM8K/XCOPA; DESIGN.md §5).
//!
//! Task files are generated at artifact-build time by
//! `python/compile/tasks.py`; scoring follows lm-evaluation-harness
//! multiple-choice convention: the correct continuation token must
//! out-rank the three distractors in the model's next-token logits at the
//! answer position.

pub mod score;
pub mod tasks;

pub use score::{evaluate_file, Accuracy};
pub use tasks::{load_eval_file, EvalTask, FAMILIES};

//! Eval task files: `prompt tokens|4 option tokens|answer index` lines.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// The five task families (fixed by `python/compile/tasks.py`).
pub const FAMILIES: [&str; 5] = ["copy_last", "induction", "assoc", "maxsym", "modsum"];

/// One multiple-choice task instance.
#[derive(Clone, Debug)]
pub struct EvalTask {
    pub prompt: Vec<i32>,
    pub options: [i32; 4],
    pub answer: usize,
}

/// Parse one eval file.
pub fn load_eval_file(path: &Path) -> Result<Vec<EvalTask>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading eval file {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('|').collect();
        if parts.len() != 3 {
            bail!("{}:{}: expected 3 |-fields", path.display(), ln + 1);
        }
        let prompt: Vec<i32> = parts[0]
            .split_whitespace()
            .map(|t| t.parse().context("prompt token"))
            .collect::<Result<_>>()?;
        let opts: Vec<i32> = parts[1]
            .split_whitespace()
            .map(|t| t.parse().context("option token"))
            .collect::<Result<_>>()?;
        if opts.len() != 4 {
            bail!("{}:{}: expected 4 options", path.display(), ln + 1);
        }
        let answer: usize = parts[2].trim().parse()?;
        if answer >= 4 {
            bail!("{}:{}: answer index out of range", path.display(), ln + 1);
        }
        out.push(EvalTask {
            prompt,
            options: [opts[0], opts[1], opts[2], opts[3]],
            answer,
        });
    }
    Ok(out)
}

/// Enumerate available eval files as (family, variant, path).
pub fn list_eval_files(eval_dir: &Path) -> Result<Vec<(String, u32, std::path::PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(eval_dir)
        .with_context(|| format!("eval dir {} — run `make artifacts`", eval_dir.display()))?
    {
        let path = entry?.path();
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
        // <family>_<variant>; family itself contains underscores
        let Some(idx) = stem.rfind('_') else { continue };
        let (fam, var) = stem.split_at(idx);
        if let Ok(v) = var[1..].parse::<u32>() {
            out.push((fam.to_string(), v, path));
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parses_task_lines() {
        let dir = std::env::temp_dir().join("hfa_eval_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("copy_last_4.txt");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "# header\n2 10 11 3|10 11 12 13|1").unwrap();
        let tasks = load_eval_file(&p).unwrap();
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].prompt, vec![2, 10, 11, 3]);
        assert_eq!(tasks[0].options, [10, 11, 12, 13]);
        assert_eq!(tasks[0].answer, 1);
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("hfa_eval_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad_1.txt");
        std::fs::write(&p, "1 2 3|4 5|0\n").unwrap();
        assert!(load_eval_file(&p).is_err());
    }

    #[test]
    fn lists_files_with_variants() {
        let dir = std::env::temp_dir().join("hfa_eval_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("copy_last_8.txt"), "").unwrap();
        std::fs::write(dir.join("modsum_2.txt"), "").unwrap();
        let files = list_eval_files(&dir).unwrap();
        assert!(files.iter().any(|(f, v, _)| f == "copy_last" && *v == 8));
        assert!(files.iter().any(|(f, v, _)| f == "modsum" && *v == 2));
    }
}

//! Multiple-choice scoring (lm-evaluation-harness convention) and logit
//! error measurement for the Table I/II/III studies.

use std::path::Path;

use anyhow::Result;

use crate::arith::mitchell::MitchellHistogram;
use crate::model::{AttnSelect, Transformer};

/// Accuracy over one task set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Accuracy {
    pub correct: usize,
    pub total: usize,
}

impl Accuracy {
    pub fn pct(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.total as f64
        }
    }
}

/// Score a task file with the given attention implementation.
/// `limit` caps the number of tasks (speed knob for benches).
pub fn evaluate_file(
    model: &Transformer,
    path: &Path,
    attn: AttnSelect,
    limit: usize,
    hist: &mut Option<&mut MitchellHistogram>,
) -> Result<Accuracy> {
    let tasks = super::tasks::load_eval_file(path)?;
    let mut correct = 0;
    let mut total = 0;
    for task in tasks.iter().take(limit) {
        let logits = model.forward(&task.prompt, attn, hist)?;
        let last = logits.row(logits.rows - 1);
        let pred = task
            .options
            .iter()
            .enumerate()
            .max_by(|(_, &a), (_, &b)| {
                last[a as usize].partial_cmp(&last[b as usize]).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        correct += usize::from(pred == task.answer);
        total += 1;
    }
    Ok(Accuracy { correct, total })
}

/// Mean |Δlogit| between an attention variant and the exact path over a
/// task sample — the Table III error measure ("total induced error" in
/// the output logits).
pub fn mean_logit_error(
    model: &Transformer,
    path: &Path,
    attn: AttnSelect,
    limit: usize,
) -> Result<f64> {
    let tasks = super::tasks::load_eval_file(path)?;
    let mut err_sum = 0.0f64;
    let mut count = 0usize;
    for task in tasks.iter().take(limit) {
        let base = model.forward(&task.prompt, AttnSelect::Exact, &mut None)?;
        let got = model.forward(&task.prompt, attn, &mut None)?;
        for (a, b) in got.data.iter().zip(&base.data) {
            err_sum += (a - b).abs() as f64;
            count += 1;
        }
    }
    Ok(err_sum / count.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_pct() {
        let a = Accuracy { correct: 3, total: 4 };
        assert_eq!(a.pct(), 75.0);
        assert_eq!(Accuracy { correct: 0, total: 0 }.pct(), 0.0);
    }
}

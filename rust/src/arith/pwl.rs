//! 8-segment piecewise-linear approximation of `2^-f`, `f in [0,1)`
//! (paper Eq. 19 and Section V-A step 3).
//!
//! In hardware the coefficients live in two small LUTs indexed by the top
//! 3 bits of the Q7 fractional input; the remaining 4 bits multiply the
//! slope.  Coefficients are endpoint-interpolated in Q14 from a closed
//! form evaluated in f64 — the *same* expression as
//! `logmath.pwl_tables()`, so both languages derive identical tables
//! (verified against `artifacts/golden/pwl_table.txt`).

use super::fix::{FRAC_BITS, FRAC_MASK};

/// Number of PWL segments over [0, 1).
pub const SEGMENTS: usize = 8;
/// Bits of the segment index.
pub const SEG_BITS: u32 = 3;
/// Low bits indexing within a segment.
pub const IN_BITS: u32 = FRAC_BITS - SEG_BITS; // 4
/// Q-format of the coefficients.
pub const COEF_BITS: u32 = 14;
/// Shifts beyond this underflow the Q7 result to zero.
pub const MAX_SHIFT: i32 = 24;

/// floor(x + 0.5): identical rounding in python and rust.
fn round_half_away(x: f64) -> i64 {
    (x + 0.5).floor() as i64
}

/// Compute the (C0, C1) Q14 coefficient tables.
pub fn tables() -> ([i32; SEGMENTS], [i32; SEGMENTS]) {
    let mut c0 = [0i32; SEGMENTS];
    let mut c1 = [0i32; SEGMENTS];
    for j in 0..SEGMENTS {
        let y0 = 2f64.powf(-(j as f64 / 8.0));
        let y1 = 2f64.powf(-((j as f64 + 1.0) / 8.0));
        c0[j] = round_half_away(y0 * (1 << COEF_BITS) as f64) as i32;
        c1[j] = round_half_away((y0 - y1) * (1 << COEF_BITS) as f64 / 16.0) as i32;
    }
    (c0, c1)
}

/// The baked tables (computed once; `tables()` is pure).
pub static PWL_C0: [i32; SEGMENTS] = [16384, 15024, 13777, 12634, 11585, 10624, 9742, 8933];
pub static PWL_C1: [i32; SEGMENTS] = [85, 78, 71, 66, 60, 55, 51, 46];

/// Q14 approximation of `2^{-f/128}` for a Q7 fraction `f` in [0, 128).
#[inline]
pub fn pow2_neg_frac_q14(f: i32) -> i32 {
    debug_assert!((0..128).contains(&f));
    let j = (f >> IN_BITS) as usize;
    let u = f & ((1 << IN_BITS) - 1);
    PWL_C0[j] - PWL_C1[j] * u
}

/// Full `2^{-d}` for a non-negative Q9.7 distance `d`, returned in Q7
/// (the correction term of Eq. 17): `2^{-f} >> p` with truncation.
#[inline]
pub fn pow2_neg_q7(d: i32) -> i32 {
    debug_assert!(d >= 0);
    let p = d >> FRAC_BITS;
    let f = d & FRAC_MASK;
    let shift = (p + (COEF_BITS - FRAC_BITS) as i32).min(MAX_SHIFT);
    pow2_neg_frac_q14(f) >> shift
}

/// Continuous (f64) evaluation of the same PWL — used by the functional
/// ablation path and to bound the approximation error in tests.
pub fn pow2_neg_pwl_f64(dist: f64) -> f64 {
    let p = dist.floor();
    let f = dist - p;
    let j = ((f * 8.0) as usize).min(7);
    let y0 = 2f64.powf(-(j as f64 / 8.0));
    let y1 = 2f64.powf(-((j as f64 + 1.0) / 8.0));
    let y = y0 + (y1 - y0) * (f * 8.0 - j as f64);
    y * 2f64.powf(-p.min(1000.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baked_tables_match_closed_form() {
        let (c0, c1) = tables();
        assert_eq!(c0, PWL_C0);
        assert_eq!(c1, PWL_C1);
    }

    #[test]
    fn endpoints_are_exact_ish() {
        // f = 0 -> 2^0 = 1.0 in Q14
        assert_eq!(pow2_neg_frac_q14(0), 1 << COEF_BITS);
        // f = 64 -> 2^-0.5 ~ 0.7071 -> 11585 in Q14
        let v = pow2_neg_frac_q14(64) as f64 / (1 << COEF_BITS) as f64;
        assert!((v - 0.70710678).abs() < 2e-3, "{v}");
    }

    #[test]
    fn pwl_error_bounded() {
        // max abs error of the 8-segment endpoint fit of 2^-x is < 1.5e-3
        for f in 0..128 {
            let approx = pow2_neg_frac_q14(f) as f64 / (1 << COEF_BITS) as f64;
            let exact = 2f64.powf(-(f as f64) / 128.0);
            assert!((approx - exact).abs() < 1.5e-3, "f={f}");
        }
    }

    #[test]
    fn shift_truncates_to_zero() {
        assert_eq!(pow2_neg_q7(0), 128); // 2^0 = 1.0 in Q7
        assert_eq!(pow2_neg_q7(128), 64); // 2^-1 = 0.5
        assert_eq!(pow2_neg_q7(30 << FRAC_BITS), 0); // deep underflow
    }

    #[test]
    fn monotone_nonincreasing() {
        let mut prev = i32::MAX;
        for d in 0..(16 << FRAC_BITS) {
            let v = pow2_neg_q7(d);
            assert!(v <= prev);
            prev = v;
        }
    }
}

//! Logarithmic number system: sign + Q9.7 `log2|x|` (paper Eq. 3) and the
//! signed LNS addition of Eqs. 10/14/17.
//!
//! Bit-exact mirror of `logmath.bf16_bits_to_log_q7`, `log_q7_to_bf16_bits`
//! and `lns_add` on finite inputs; exponent-0xFF BF16 bits (Inf/NaN),
//! which the python spec leaves undefined, are handled explicitly in
//! [`Lns::from_bf16`] (saturate / drop) instead of flowing through as
//! out-of-range logs.

use super::bf16::Bf16;
use super::fix::{is_log_zero, BF16_BIAS, FRAC_BITS, FRAC_MASK, LOG_ZERO};
use super::pwl;

/// An LNS value: `(-1)^sign * 2^(log/128)`; `log == LOG_ZERO` encodes 0.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Lns {
    pub sign: i32,
    pub log: i32,
}

impl Lns {
    pub const ZERO: Lns = Lns { sign: 0, log: LOG_ZERO };

    #[inline]
    pub fn is_zero(self) -> bool {
        is_log_zero(self.log)
    }

    /// The Q9.7 log of the largest finite BF16 (`0x7F7F`): where
    /// non-finite inputs saturate on conversion.
    pub const MAX_FINITE_LOG: i32 = 0x7F7F - (BF16_BIAS << FRAC_BITS);

    /// Float -> log conversion of the value vector (Eq. 18): reinterpret
    /// the BF16 exponent.mantissa as Q8.7 and subtract the bias —
    /// Mitchell's `log2(1+M) ~= M`.  Zero/subnormal -> LNS zero.
    ///
    /// Non-finite BF16 bits (exponent `0xFF`) have no log-domain
    /// representation; reinterpreting them as Q8.7 used to yield a
    /// "log" *above* every finite value that then flowed through the
    /// datapath as if valid.  They are handled explicitly instead:
    /// +-Inf saturates to the log of the largest finite BF16
    /// ([`Lns::MAX_FINITE_LOG`], mirroring the `to_bf16` overflow
    /// convention), and NaN converts to LNS zero (a poisoned lane is
    /// dropped rather than injected as a huge magnitude).
    #[inline]
    pub fn from_bf16(v: Bf16) -> Lns {
        let bits = v.bits() as i32;
        let sign = bits >> 15 & 1;
        if bits & 0x7F80 == 0 {
            // zero/subnormal -> sentinel, preserving the sign bit
            // (matches the python spec; the sign of a zero operand is
            // never propagated by lns_add)
            return Lns { sign, log: LOG_ZERO };
        }
        if bits & 0x7F80 == 0x7F80 {
            let log = if bits & 0x7F == 0 { Lns::MAX_FINITE_LOG } else { LOG_ZERO };
            return Lns { sign, log };
        }
        Lns { sign, log: (bits & 0x7FFF) - (BF16_BIAS << FRAC_BITS) }
    }

    /// Log -> float back-conversion (Eq. 22): `2^(I+F) ~= 2^I * (1+F)`,
    /// so the Q9.7 integer part (plus bias) becomes the exponent field and
    /// the fraction bits become the mantissa.  Underflow saturates to
    /// +-0, overflow to the max finite BF16.
    #[inline]
    pub fn to_bf16(self) -> Bf16 {
        if self.is_zero() {
            return Bf16(((self.sign as u16) & 1) << 15);
        }
        let i_part = self.log >> FRAC_BITS; // arithmetic shift = floor
        let f_part = self.log & FRAC_MASK;
        let ebits = i_part + BF16_BIAS;
        let s = (self.sign as u16 & 1) << 15;
        if ebits <= 0 {
            Bf16(s) // exponent underflow -> signed zero
        } else if ebits >= 255 {
            Bf16(s | (254 << FRAC_BITS) | FRAC_MASK as u16) // saturate
        } else {
            Bf16(s | ((ebits as u16) << FRAC_BITS) | f_part as u16)
        }
    }

    /// Multiply by `2^(dq/128)` (Q9.7 add in log domain).
    #[inline]
    pub fn scaled(self, dq: i32) -> Lns {
        Lns { sign: self.sign, log: super::fix::shift_log(self.log, dq) }
    }

    /// Negate.
    #[inline]
    pub fn neg(self) -> Lns {
        Lns { sign: self.sign ^ 1, log: self.log }
    }

    /// f64 value (diagnostics only).
    pub fn to_f64(self) -> f64 {
        if self.is_zero() {
            0.0
        } else {
            let mag = 2f64.powf(self.log as f64 / 128.0);
            if self.sign == 1 { -mag } else { mag }
        }
    }
}

/// Signed LNS addition (Eqs. 14a/14d with Mitchell Eq. 17 and PWL Eq. 19):
///
/// `L = max(A,B) +- (PWL(2^-f) >> p)`; sign = sign of the larger operand
/// (ties -> the B operand, matching `B >= A -> s_b` in Eq. 14d).
#[inline]
pub fn lns_add(a: Lns, b: Lns) -> Lns {
    lns_add_traced(a, b, None)
}

/// `lns_add` with optional Fig.-5 instrumentation: records the Mitchell
/// input `x = 2^-|A-B|` whenever the approximation `log2(1 +- x) ~= +-x`
/// is actually applied (both operands non-zero).
#[inline]
pub fn lns_add_traced(
    a: Lns,
    b: Lns,
    hist: Option<&mut super::mitchell::MitchellHistogram>,
) -> Lns {
    if a.is_zero() {
        if b.is_zero() {
            return Lns::ZERO;
        }
        return b;
    }
    if b.is_zero() {
        return a;
    }
    let d = (a.log - b.log).abs();
    let r = pwl::pow2_neg_q7(d);
    if let Some(h) = hist {
        h.record_q7(r.min(127));
    }
    let mx = a.log.max(b.log);
    let log = if a.sign == b.sign { mx + r } else { mx - r };
    let sign = if a.log > b.log { a.sign } else { b.sign };
    Lns { sign, log }
}

/// `Lns::from_bf16` with optional Fig.-5 instrumentation: records the
/// Mitchell input `x = M_V` (the mantissa fraction of Eq. 18).
#[inline]
pub fn from_bf16_traced(v: Bf16, hist: Option<&mut super::mitchell::MitchellHistogram>) -> Lns {
    let l = Lns::from_bf16(v);
    if !l.is_zero() {
        if let Some(h) = hist {
            h.record_q7((v.bits() & 0x7F) as i32);
        }
    }
    l
}

/// A slice-wise LNS lane vector (the `d+1` lanes of the merged
/// `O = [ell, o]` accumulator of Eq. 12).
#[derive(Clone, Debug, PartialEq)]
pub struct LnsVec {
    pub signs: Vec<i32>,
    pub logs: Vec<i32>,
}

impl LnsVec {
    pub fn zeros(n: usize) -> LnsVec {
        LnsVec { signs: vec![0; n], logs: vec![LOG_ZERO; n] }
    }

    pub fn len(&self) -> usize {
        self.signs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.signs.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> Lns {
        Lns { sign: self.signs[i], log: self.logs[i] }
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: Lns) {
        self.signs[i] = v.sign;
        self.logs[i] = v.log;
    }

    /// Lane-wise `self = lns_add(self.scaled(dq_self), rhs.scaled(dq_rhs))`
    /// — one step of the Eq. 14 recurrence across all d+1 lanes.
    pub fn fused_update(&mut self, dq_self: i32, rhs: &LnsVec, dq_rhs: i32) {
        debug_assert_eq!(self.len(), rhs.len());
        for i in 0..self.len() {
            let a = self.get(i).scaled(dq_self);
            let b = rhs.get(i).scaled(dq_rhs);
            self.set(i, lns_add(a, b));
        }
    }
}

/// A dense SoA matrix of LNS values: `rows x lanes`, signs and logs in
/// flat row-major storage so each row is one contiguous slice per plane.
/// This is the resident layout of a prepared value matrix (`d+1` lanes
/// per row, lane 0 = the prepended ell constant of Eq. 12): the serving
/// hot loop reads `row_signs`/`row_logs` straight into the Eq.-14 lane
/// update with no per-row allocation or copy.
#[derive(Clone, Debug, PartialEq)]
pub struct LnsMat {
    rows: usize,
    lanes: usize,
    signs: Vec<i32>,
    logs: Vec<i32>,
}

impl LnsMat {
    pub fn zeros(rows: usize, lanes: usize) -> LnsMat {
        LnsMat {
            rows,
            lanes,
            signs: vec![0; rows * lanes],
            logs: vec![LOG_ZERO; rows * lanes],
        }
    }

    /// An empty (0-row) lane matrix preallocated for `row_capacity`
    /// rows, so growing it row-by-row up to that capacity never
    /// reallocates — the backing store of a fixed-capacity KV chunk.
    pub fn with_row_capacity(row_capacity: usize, lanes: usize) -> LnsMat {
        LnsMat {
            rows: 0,
            lanes,
            signs: Vec::with_capacity(row_capacity * lanes),
            logs: Vec::with_capacity(row_capacity * lanes),
        }
    }

    /// Grow both planes geometrically (at least doubling) when one more
    /// row would not fit.  A cloned `Vec` starts at exact capacity, so
    /// without this a per-token push loop over a copy-on-write clone
    /// pays one realloc + full memcpy per token (O(T^2) over a decode).
    fn reserve_amortized_row(&mut self) {
        let need = self.signs.len() + self.lanes;
        if need > self.signs.capacity() {
            let target = need.max(self.signs.capacity() * 2);
            self.signs.reserve_exact(target - self.signs.len());
            self.logs.reserve_exact(target - self.logs.len());
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    #[inline]
    pub fn row_signs(&self, r: usize) -> &[i32] {
        &self.signs[r * self.lanes..(r + 1) * self.lanes]
    }

    #[inline]
    pub fn row_logs(&self, r: usize) -> &[i32] {
        &self.logs[r * self.lanes..(r + 1) * self.lanes]
    }

    /// Overwrite row `r` from an [`LnsVec`] (must have `lanes` entries).
    pub fn set_row(&mut self, r: usize, v: &LnsVec) {
        assert_eq!(v.len(), self.lanes, "lane count mismatch");
        self.signs[r * self.lanes..(r + 1) * self.lanes].copy_from_slice(&v.signs);
        self.logs[r * self.lanes..(r + 1) * self.lanes].copy_from_slice(&v.logs);
    }

    /// Append one row (must have `lanes` entries) below the existing rows
    /// — the decode-time growth primitive for a resident value matrix.
    /// Only the new row's planes are written; resident rows are untouched
    /// (at most one realloc memcpy of the flat storage, geometrically
    /// amortized).
    pub fn push_row(&mut self, v: &LnsVec) {
        self.push_row_slices(&v.signs, &v.logs);
    }

    /// [`LnsMat::push_row`] from raw plane slices (zero-copy interop
    /// with resident rows of another `LnsMat`).
    pub fn push_row_slices(&mut self, signs: &[i32], logs: &[i32]) {
        assert_eq!(signs.len(), self.lanes, "lane count mismatch");
        assert_eq!(logs.len(), self.lanes, "lane count mismatch");
        self.reserve_amortized_row();
        self.signs.extend_from_slice(signs);
        self.logs.extend_from_slice(logs);
        self.rows += 1;
    }

    /// Copy row `r` out as an [`LnsVec`] (interop with the merge path).
    pub fn row_vec(&self, r: usize) -> LnsVec {
        LnsVec {
            signs: self.row_signs(r).to_vec(),
            logs: self.row_logs(r).to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lns(v: f32) -> Lns {
        Lns::from_bf16(Bf16::from_f32(v))
    }

    #[test]
    fn bf16_log_roundtrip_powers_of_two() {
        // powers of two have zero mantissa -> Mitchell is exact
        for &x in &[1.0f32, 2.0, 4.0, 0.5, 0.25, -8.0, -0.125] {
            let l = lns(x);
            assert_eq!(l.to_bf16().to_f32(), x, "{x}");
        }
    }

    #[test]
    fn log_of_one_is_zero() {
        assert_eq!(lns(1.0), Lns { sign: 0, log: 0 });
        assert_eq!(lns(-1.0), Lns { sign: 1, log: 0 });
    }

    #[test]
    fn zero_encodes_sentinel() {
        assert!(lns(0.0).is_zero());
        assert_eq!(lns(0.0).to_bf16(), Bf16::ZERO);
    }

    #[test]
    fn mitchell_conversion_bias() {
        // log2|1.5| = 0.585; Mitchell gives M = 0.5 (error 0.085 < 0.086)
        let l = lns(1.5);
        assert_eq!(l.log, 64); // 0.5 in Q7
    }

    #[test]
    fn add_equal_positive_doubles() {
        // 1 + 1 = 2 exactly: d=0 -> r=128 (Q7 of 1.0) -> log 0+128
        let r = lns_add(lns(1.0), lns(1.0));
        assert_eq!(r.to_bf16().to_f32(), 2.0);
    }

    #[test]
    fn add_cancellation_halves_not_zeroes() {
        // Mitchell artefact (Eq. 17): x + (-x) gives max - 1.0 in log2,
        // i.e. magnitude x/2, not 0 — documented datapath behaviour.
        // Sign on a tie follows operand B (Eq. 14d: B >= A -> s_b).
        let r = lns_add(lns(4.0), lns(-4.0));
        assert_eq!(r.to_bf16().to_f32(), -2.0);
        let r = lns_add(lns(-4.0), lns(4.0));
        assert_eq!(r.to_bf16().to_f32(), 2.0);
    }

    #[test]
    fn add_sign_follows_larger() {
        let r = lns_add(lns(-8.0), lns(1.0));
        assert_eq!(r.sign, 1);
        let r = lns_add(lns(8.0), lns(-1.0));
        assert_eq!(r.sign, 0);
    }

    #[test]
    fn add_zero_identity() {
        let x = lns(3.0);
        assert_eq!(lns_add(x, Lns::ZERO), x);
        assert_eq!(lns_add(Lns::ZERO, x), x);
        assert_eq!(lns_add(Lns::ZERO, Lns::ZERO), Lns::ZERO);
    }

    #[test]
    fn add_approx_accuracy_vs_exact() {
        // across random positive pairs the LNS sum is within *two stacked*
        // Mitchell errors (from_bf16 conversion ~0.086 + Eq. 17 add ~0.086)
        // plus PWL/quantization slack
        let mut worst: f64 = 0.0;
        let mut x = 0.37f32;
        for i in 0..500 {
            let a = x * (1.0 + (i % 17) as f32);
            let b = 0.11f32 * (1.0 + (i % 29) as f32);
            let r = lns_add(lns(a), lns(b)).to_f64();
            let exact = (Bf16::from_f32(a).to_f32() + Bf16::from_f32(b).to_f32()) as f64;
            worst = worst.max((r.log2() - exact.log2()).abs());
            x = (x * 1.07).rem_euclid(5.0) + 0.01;
        }
        assert!(worst < 0.19, "worst log2 error {worst}");
    }

    #[test]
    fn non_finite_bf16_saturates_or_drops_at_conversion() {
        // regression: exponent-0xFF bits used to reinterpret as a "log"
        // above every finite value and flow through the datapath as
        // valid.  Pinned behaviour: +-Inf saturates to the largest
        // finite log, NaN converts to LNS zero.
        let pos_inf = Lns::from_bf16(Bf16(0x7F80));
        assert_eq!(pos_inf, Lns { sign: 0, log: Lns::MAX_FINITE_LOG });
        assert_eq!(pos_inf.to_bf16(), Bf16::MAX_FINITE, "Inf round-trips to max finite");
        let neg_inf = Lns::from_bf16(Bf16(0xFF80));
        assert_eq!(neg_inf, Lns { sign: 1, log: Lns::MAX_FINITE_LOG });
        for nan_bits in [0x7FC0u16, 0x7F81, 0xFFC0, 0xFFFF] {
            let l = Lns::from_bf16(Bf16(nan_bits));
            assert!(l.is_zero(), "NaN bits {nan_bits:#06x} must convert to LNS zero");
        }
        // f32 overflow path: values that round up to BF16 Inf saturate too
        let l = Lns::from_bf16(Bf16::from_f32(f32::MAX));
        assert_eq!(l.log, Lns::MAX_FINITE_LOG);
        assert!(Lns::from_bf16(Bf16::from_f32(f32::NAN)).is_zero());
        // the largest finite BF16 itself is unchanged by the guard
        let max_fin = Lns::from_bf16(Bf16::MAX_FINITE);
        assert_eq!(max_fin, Lns { sign: 0, log: Lns::MAX_FINITE_LOG });
        // a non-finite operand no longer dominates an lns_add unboundedly
        let sum = lns_add(pos_inf, lns(1.0));
        assert!(sum.log <= Lns::MAX_FINITE_LOG + 128, "saturated add stays bounded");
    }

    #[test]
    fn lnsmat_growth_is_geometric_even_after_exact_capacity_clone() {
        let row = LnsVec { signs: vec![0, 1, 0], logs: vec![5, -7, LOG_ZERO] };
        let mut base = LnsMat::zeros(50, 3);
        for r in 0..50 {
            base.set_row(r, &row);
        }
        let mut m = base.clone(); // exact-capacity clone
        let mut caps = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            m.push_row(&row);
            caps.insert(m.signs.capacity());
        }
        assert_eq!(m.rows(), 1050);
        assert!(
            caps.len() <= 8,
            "capacity changed {} times over 1000 pushes — growth is not geometric",
            caps.len()
        );
        // preallocated chunk storage never reallocates up to capacity
        let mut pre = LnsMat::with_row_capacity(64, 3);
        let cap0 = pre.signs.capacity();
        for _ in 0..64 {
            pre.push_row_slices(&row.signs, &row.logs);
        }
        assert_eq!(pre.signs.capacity(), cap0);
        assert_eq!(pre.rows(), 64);
        assert_eq!(pre.row_vec(63), row);
    }

    #[test]
    fn back_conversion_saturates() {
        let big = Lns { sign: 0, log: 200 << FRAC_BITS };
        assert_eq!(big.to_bf16(), Bf16(0x7F7F));
        let tiny = Lns { sign: 1, log: -(200 << FRAC_BITS) };
        assert_eq!(tiny.to_bf16(), Bf16(0x8000));
    }

    #[test]
    fn lnsmat_rows_roundtrip() {
        let mut m = LnsMat::zeros(3, 4);
        let row = LnsVec {
            signs: vec![0, 1, 0, 1],
            logs: vec![0, 64, LOG_ZERO, -32],
        };
        m.set_row(1, &row);
        assert_eq!(m.row_vec(1), row);
        assert_eq!(m.row_signs(1), &row.signs[..]);
        assert_eq!(m.row_logs(1), &row.logs[..]);
        // untouched rows stay LNS-zero
        for i in 0..4 {
            assert!(m.row_vec(0).get(i).is_zero());
            assert!(m.row_vec(2).get(i).is_zero());
        }
    }

    #[test]
    fn lnsmat_push_row_matches_set_row_build() {
        // growing row-by-row must equal building the full matrix up front
        let rows: Vec<LnsVec> = (0..5)
            .map(|r| LnsVec {
                signs: vec![r as i32 % 2, 0, 1],
                logs: vec![r as i32 * 7 - 3, LOG_ZERO, 64 - r as i32],
            })
            .collect();
        let mut grown = LnsMat::zeros(0, 3);
        let mut full = LnsMat::zeros(5, 3);
        for (r, v) in rows.iter().enumerate() {
            grown.push_row(v);
            full.set_row(r, v);
        }
        assert_eq!(grown, full);
        assert_eq!(grown.rows(), 5);
        assert_eq!(grown.row_signs(2), full.row_signs(2));
        assert_eq!(grown.row_logs(4), full.row_logs(4));
    }

    #[test]
    fn lnsvec_fused_update_matches_scalar() {
        let mut v = LnsVec::zeros(3);
        let rhs = LnsVec {
            signs: vec![0, 1, 0],
            logs: vec![0, 64, LOG_ZERO],
        };
        v.fused_update(-10, &rhs, -5);
        for i in 0..3 {
            let expect = lns_add(Lns::ZERO.scaled(-10), rhs.get(i).scaled(-5));
            assert_eq!(v.get(i), expect);
        }
    }
}

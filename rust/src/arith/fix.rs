//! Q9.7 fixed-point format of the H-FA log domain (paper Section IV-B).
//!
//! 16-bit in hardware (9 integer bits incl. sign + 7 fraction bits — the
//! 7 matches BFloat16's mantissa width so the float->log conversion of
//! Eq. 18 is a pure bit reinterpretation).  We carry values in `i32` like
//! the python/jnp emulation; the extra headroom never changes results
//! because every operation's range is within Q9.7 after the [-15, 0]
//! clamp.

/// Fraction bits of the Q9.7 format.
pub const FRAC_BITS: u32 = 7;
/// 1.0 in Q9.7.
pub const FRAC_ONE: i32 = 1 << FRAC_BITS;
/// Fraction mask.
pub const FRAC_MASK: i32 = FRAC_ONE - 1;
/// -inf sentinel (logarithm of zero) — far below any reachable value.
pub const LOG_ZERO: i32 = -(1 << 24);
/// Score differences are clamped to [-15, 0] before quantization
/// (e^-15 ~ 3e-7 is below BF16 resolution — paper Section IV-B).
pub const CLAMP_LO: f32 = -15.0;
/// log2(e) in f32, the score-difference scale factor (e^x = 2^{x log2 e}).
pub const LOG2E_F32: f32 = 1.442_695_f32;
/// BFloat16 exponent bias.
pub const BF16_BIAS: i32 = 127;

/// Is this log value the -inf sentinel? (mirrors `logq <= LOG_ZERO // 2`)
#[inline]
pub fn is_log_zero(l: i32) -> bool {
    l <= LOG_ZERO / 2
}

/// `quant[(dz) * log2 e]` of Eqs. 14b/14c/16b/16c: clamp the (non-positive,
/// natural-log-unit) f32 score difference to [-15, 0], scale by log2(e) in
/// f32, truncate (floor) to Q9.7.  NaN (the -inf - -inf warmup case) maps
/// to the clamp floor, matching the python spec.
#[inline]
pub fn quant_diff_q7(dz: f32) -> i32 {
    let dz = if dz.is_nan() { CLAMP_LO } else { dz };
    let dz = dz.clamp(CLAMP_LO, 0.0);
    let t = dz * LOG2E_F32;
    (t * FRAC_ONE as f32).floor() as i32
}

/// Q9.7 -> f64 (for diagnostics / functional paths; not used in the
/// bit-exact pipeline).
#[inline]
pub fn q7_to_f64(q: i32) -> f64 {
    if is_log_zero(q) {
        f64::NEG_INFINITY
    } else {
        q as f64 / FRAC_ONE as f64
    }
}

/// f64 -> Q9.7 with truncation toward -inf (hardware truncation).
#[inline]
pub fn f64_to_q7_trunc(x: f64) -> i32 {
    if x == f64::NEG_INFINITY {
        LOG_ZERO
    } else {
        (x * FRAC_ONE as f64).floor() as i32
    }
}

/// Saturating Q9.7 add with LOG_ZERO propagation: multiplying by `2^dq`
/// in the log domain (`shift_log` of the python spec).
#[inline]
pub fn shift_log(logq: i32, dq: i32) -> i32 {
    if is_log_zero(logq) {
        LOG_ZERO
    } else {
        logq + dq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_clamps_and_floors() {
        assert_eq!(quant_diff_q7(0.0), 0);
        assert_eq!(quant_diff_q7(-1e9), quant_diff_q7(-15.0));
        assert_eq!(quant_diff_q7(f32::NEG_INFINITY), quant_diff_q7(-15.0));
        assert_eq!(quant_diff_q7(f32::NAN), quant_diff_q7(-15.0));
        // positive inputs clamp to 0 (differences are non-positive by def)
        assert_eq!(quant_diff_q7(3.0), 0);
        // -1 nat -> -log2(e) ~ -1.4427 -> floor(-184.66.) = -185
        assert_eq!(quant_diff_q7(-1.0), -185);
    }

    #[test]
    fn quant_monotone_nonincreasing() {
        let mut prev = quant_diff_q7(0.0);
        let mut x = 0.0f32;
        while x > -16.0 {
            let q = quant_diff_q7(x);
            assert!(q <= prev || q == prev, "quant not monotone at {x}");
            prev = q.min(prev);
            x -= 0.013;
        }
    }

    #[test]
    fn shift_log_propagates_sentinel() {
        assert_eq!(shift_log(LOG_ZERO, -100), LOG_ZERO);
        assert_eq!(shift_log(256, -128), 128);
    }

    #[test]
    fn q7_f64_roundtrip_on_grid() {
        for q in [-2048, -1, 0, 1, 127, 128, 4095] {
            assert_eq!(f64_to_q7_trunc(q7_to_f64(q)), q);
        }
        assert_eq!(f64_to_q7_trunc(q7_to_f64(LOG_ZERO)), LOG_ZERO);
    }
}

//! Mitchell's approximation `log2(1 +- x) ~= +-x` — error analysis and the
//! Fig. 5 instrumentation.
//!
//! The paper's Section VI-B studies *where* Mitchell's approximation is
//! applied (value-vector mantissas in Eq. 18, the `2^-|A-B|` correction in
//! Eq. 17) and shows the input distribution concentrates below 0.1 where
//! the absolute error is < 0.02, bounded overall by ~0.086.  This module
//! provides the exact error function and a histogram recorder that the
//! H-FA golden model fills while processing real eval traffic.

/// Absolute Mitchell error `E(x) = |log2(1 + x) - x|` for the addition
/// branch, `x in [0, 1)`.
pub fn error_add(x: f64) -> f64 {
    ((1.0 + x).log2() - x).abs()
}

/// Absolute error of the subtraction branch `|log2(1 - x) - (-x)|`
/// (unbounded as x -> 1; the paper's Fig. 5 plots the + branch).
pub fn error_sub(x: f64) -> f64 {
    if x >= 1.0 {
        f64::INFINITY
    } else {
        ((1.0 - x).log2() + x).abs()
    }
}

/// Peak of `E(x)`: x* = 1/ln2 - 1, E(x*) ~= 0.0860.
pub fn max_error_add() -> (f64, f64) {
    let x = 1.0 / std::f64::consts::LN_2 - 1.0;
    (x, error_add(x))
}

/// Histogram of inputs to Mitchell's approximation over [0, 1).
#[derive(Clone, Debug)]
pub struct MitchellHistogram {
    pub bins: Vec<u64>,
    pub total: u64,
}

impl MitchellHistogram {
    pub fn new(nbins: usize) -> Self {
        MitchellHistogram { bins: vec![0; nbins], total: 0 }
    }

    #[inline]
    pub fn record(&mut self, x: f64) {
        if !(0.0..1.0).contains(&x) {
            return;
        }
        let idx = ((x * self.bins.len() as f64) as usize).min(self.bins.len() - 1);
        self.bins[idx] += 1;
        self.total += 1;
    }

    /// Record a Q7 fraction input (the `2^-|A-B|` term of Eq. 17).
    #[inline]
    pub fn record_q7(&mut self, q7: i32) {
        self.record(q7 as f64 / 128.0);
    }

    /// Fraction of recorded inputs in [0, hi).
    pub fn mass_below(&self, hi: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let cut = ((hi * self.bins.len() as f64) as usize).min(self.bins.len());
        self.bins[..cut].iter().sum::<u64>() as f64 / self.total as f64
    }

    pub fn merge(&mut self, other: &MitchellHistogram) {
        assert_eq!(self.bins.len(), other.bins.len());
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.total += other.total;
    }

    /// (bin_center, density, mitchell_error_at_center) rows — the Fig. 5
    /// series.
    pub fn rows(&self) -> Vec<(f64, f64, f64)> {
        let n = self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let x = (i as f64 + 0.5) / n;
                let dens = if self.total == 0 { 0.0 } else { c as f64 / self.total as f64 };
                (x, dens, error_add(x))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_zero_at_endpoints() {
        assert!(error_add(0.0) < 1e-12);
        assert!(error_add(1.0 - 1e-12) < 1e-9);
    }

    #[test]
    fn max_error_is_0086() {
        let (x, e) = max_error_add();
        assert!((x - 0.4427).abs() < 1e-3);
        assert!((e - 0.0860).abs() < 1e-3);
        // paper: "the absolute error can never exceed 0.08[6]"
        for i in 0..1000 {
            assert!(error_add(i as f64 / 1000.0) <= e + 1e-12);
        }
    }

    #[test]
    fn error_below_envelope_for_small_inputs() {
        // paper Fig. 5 text: "inputs below 0.1 -> error less than 0.02".
        // In base-2 (the E(x) the datapath incurs) E(0.1) = 0.0375, so the
        // 0.02 figure only holds for x < ~0.045 (E(x) ~ 0.4427x for small
        // x) — we assert the measured base-2 envelope (0.04 at x<0.1) and
        // the paper's figure at x<0.045.
        for i in 0..100 {
            assert!(error_add(i as f64 / 1000.0) < 0.04);
        }
        for i in 0..45 {
            assert!(error_add(i as f64 / 1000.0) < 0.02);
        }
    }

    #[test]
    fn histogram_mass_and_rows() {
        let mut h = MitchellHistogram::new(50);
        for i in 0..1000 {
            h.record((i % 10) as f64 / 100.0);
        }
        assert_eq!(h.total, 1000);
        assert!((h.mass_below(0.1) - 1.0).abs() < 1e-9);
        let rows = h.rows();
        assert_eq!(rows.len(), 50);
        assert!(rows[0].1 > 0.0);
    }

    #[test]
    fn sub_branch_unbounded() {
        assert!(error_sub(0.999) > 1.0);
        assert!(error_sub(0.1) < 0.06);
    }
}

//! Software BFloat16: the floating-point format of both accelerator
//! variants in the paper's evaluation ("all floating-point computations
//! refer to the BFloat16 datatype", Section VI-C).
//!
//! 1 sign + 8 exponent + 7 mantissa bits.  Conversions use
//! round-to-nearest-even, matching XLA's `f32 -> bf16` convert and the
//! `f32_to_bf16_bits` helper in `logmath.py`.

/// A BFloat16 value stored as raw bits.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3F80);
    pub const NEG_INF: Bf16 = Bf16(0xFF80);
    pub const MAX_FINITE: Bf16 = Bf16(0x7F7F);

    /// Round-to-nearest-even conversion from f32 (same as XLA / numpy).
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // canonical quiet NaN, preserving sign
            return Bf16(((bits >> 16) as u16 & 0x8000) | 0x7FC0);
        }
        let rounded = (bits as u64 + 0x7FFF + ((bits >> 16) & 1) as u64) >> 16;
        Bf16(rounded as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn from_bits(bits: u16) -> Bf16 {
        Bf16(bits)
    }

    #[inline]
    pub fn bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn sign(self) -> u16 {
        self.0 >> 15
    }

    /// Biased exponent field (8 bits).
    #[inline]
    pub fn exponent(self) -> u16 {
        (self.0 >> 7) & 0xFF
    }

    /// Mantissa field (7 bits, no hidden one).
    #[inline]
    pub fn mantissa(self) -> u16 {
        self.0 & 0x7F
    }

    /// Zero or subnormal (the H-FA log converter maps both to -inf).
    #[inline]
    pub fn is_zero_or_subnormal(self) -> bool {
        self.exponent() == 0
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        self.exponent() == 0xFF && self.mantissa() != 0
    }

    /// BF16 multiply: exact in f32 (8+8 mantissa bits fit), rounded once.
    #[inline]
    pub fn mul(self, rhs: Bf16) -> Bf16 {
        Bf16::from_f32(self.to_f32() * rhs.to_f32())
    }

    /// BF16 add, RNE-rounded result.
    #[inline]
    pub fn add(self, rhs: Bf16) -> Bf16 {
        Bf16::from_f32(self.to_f32() + rhs.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> Self {
        x.to_f32()
    }
}

/// Round an f32 slice through bf16 (the "inputs are BF16" convention used
/// throughout the golden models).
pub fn round_slice_f32(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| Bf16::from_f32(x).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.375, 65280.0] {
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x}");
        }
    }

    #[test]
    fn rne_rounding_matches_reference() {
        // 1.0 + 2^-8 rounds down to 1.0 (tie to even), 1.0 + 3*2^-9 rounds up.
        assert_eq!(Bf16::from_f32(1.0 + 1.0 / 256.0).to_f32(), 1.0);
        let up = Bf16::from_f32(1.0 + 3.0 / 512.0).to_f32();
        assert_eq!(up, 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn field_decomposition() {
        let x = Bf16::from_f32(-3.5); // sign 1, exp 128, mant 0x60
        assert_eq!(x.sign(), 1);
        assert_eq!(x.exponent(), 128);
        assert_eq!(x.mantissa(), 0x60);
    }

    #[test]
    fn zero_and_subnormal_detection() {
        assert!(Bf16::from_f32(0.0).is_zero_or_subnormal());
        assert!(Bf16(0x0001).is_zero_or_subnormal());
        assert!(!Bf16::ONE.is_zero_or_subnormal());
    }

    #[test]
    fn infinity_saturation_behaviour() {
        let inf = Bf16::from_f32(f32::INFINITY);
        assert_eq!(inf.exponent(), 0xFF);
        assert!(!inf.is_nan());
        assert!(Bf16::from_f32(f32::NAN).is_nan());
    }
}

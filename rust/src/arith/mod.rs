//! Bit-accurate arithmetic substrate of the H-FA datapath.
//!
//! Every operation here mirrors `python/compile/kernels/logmath.py`
//! bit-for-bit; `rust/tests/golden_replay.rs` pins the two together with
//! golden vectors dumped at artifact-build time.

pub mod bf16;
pub mod fix;
pub mod lns;
pub mod mitchell;
pub mod pwl;

pub use bf16::Bf16;
pub use fix::{quant_diff_q7, FRAC_BITS, FRAC_MASK, FRAC_ONE, LOG_ZERO};
pub use lns::Lns;

//! PJRT runtime: loads the AOT-compiled HLO **text** artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/hlo/*.hlo.txt`.

pub mod client;
pub mod registry;

pub use client::{Engine, LoadedExecutable};
pub use registry::{ArtifactRegistry, AttnKernelSpec};

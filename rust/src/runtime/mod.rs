//! PJRT runtime: loads the AOT-compiled HLO **text** artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/hlo/*.hlo.txt`.
//!
//! Also home to [`pool`], the persistent scoped worker pool the attention
//! hot path fans query batches out on (no per-call thread spawns).

pub mod client;
pub mod pool;
pub mod registry;

pub use client::{Engine, LoadedExecutable};
pub use pool::WorkerPool;
pub use registry::{ArtifactRegistry, AttnKernelSpec};

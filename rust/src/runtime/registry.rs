//! Artifact registry: discovers and lazily compiles the HLO modules under
//! `artifacts/hlo/`, keyed by the naming convention of `aot.py`
//! (`attn_<kind>_d<d>_n<n>_b<b>.hlo.txt`, `model_<size>_<impl>.hlo.txt`).

use std::collections::HashMap;
use std::path::PathBuf;

use crate::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::client::{Engine, LoadedExecutable};

/// Parsed name of an attention-kernel artifact.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct AttnKernelSpec {
    /// "fa2" or "hfa".
    pub kind: String,
    pub head_dim: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl AttnKernelSpec {
    pub fn file_name(&self) -> String {
        format!(
            "attn_{}_d{}_n{}_b{}.hlo.txt",
            self.kind, self.head_dim, self.seq_len, self.batch
        )
    }

    pub fn parse(stem: &str) -> Option<AttnKernelSpec> {
        // attn_<kind>_d<d>_n<n>_b<b>
        let rest = stem.strip_prefix("attn_")?;
        let mut parts = rest.split('_');
        let kind = parts.next()?.to_string();
        let d = parts.next()?.strip_prefix('d')?.parse().ok()?;
        let n = parts.next()?.strip_prefix('n')?.parse().ok()?;
        let b = parts.next()?.strip_prefix('b')?.parse().ok()?;
        Some(AttnKernelSpec { kind, head_dim: d, seq_len: n, batch: b })
    }
}

/// Lazily-compiling artifact registry (compilation cached per path).
pub struct ArtifactRegistry {
    engine: Engine,
    hlo_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<LoadedExecutable>>>,
}

impl ArtifactRegistry {
    pub fn open(artifacts_dir: &std::path::Path) -> Result<ArtifactRegistry> {
        let hlo_dir = artifacts_dir.join("hlo");
        anyhow::ensure!(
            hlo_dir.is_dir(),
            "HLO artifact dir {} missing — run `make artifacts`",
            hlo_dir.display()
        );
        Ok(ArtifactRegistry {
            engine: Engine::cpu()?,
            hlo_dir,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// All attention-kernel specs present on disk.
    pub fn list_attention_kernels(&self) -> Result<Vec<AttnKernelSpec>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.hlo_dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                if let Some(spec) = AttnKernelSpec::parse(stem) {
                    out.push(spec);
                }
            }
        }
        out.sort_by_key(|s| (s.kind.clone(), s.head_dim, s.seq_len));
        Ok(out)
    }

    /// Model sizes with a given attention impl present on disk.
    pub fn list_models(&self) -> Result<Vec<(String, String)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.hlo_dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                if let Some(rest) = stem.strip_prefix("model_") {
                    if let Some((size, imp)) = rest.split_once('_') {
                        out.push((size.to_string(), imp.to_string()));
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn load_cached(&self, file: &str) -> Result<Arc<LoadedExecutable>> {
        if let Some(e) = self.cache.lock().get(file) {
            return Ok(e.clone());
        }
        let path = self.hlo_dir.join(file);
        if !path.is_file() {
            bail!("artifact {} not found — run `make artifacts`", path.display());
        }
        let exe = Arc::new(
            self.engine
                .load_hlo_text(&path)
                .with_context(|| format!("loading {file}"))?,
        );
        self.cache.lock().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load (and cache) an attention kernel.
    pub fn attention_kernel(&self, spec: &AttnKernelSpec) -> Result<Arc<LoadedExecutable>> {
        self.load_cached(&spec.file_name())
    }

    /// Load (and cache) a full-model forward.
    pub fn model(&self, size: &str, imp: &str) -> Result<Arc<LoadedExecutable>> {
        self.load_cached(&format!("model_{size}_{imp}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_name_roundtrip() {
        let s = AttnKernelSpec { kind: "hfa".into(), head_dim: 64, seq_len: 1024, batch: 16 };
        let parsed = AttnKernelSpec::parse("attn_hfa_d64_n1024_b16").unwrap();
        assert_eq!(parsed, s);
        assert_eq!(s.file_name(), "attn_hfa_d64_n1024_b16.hlo.txt");
    }

    #[test]
    fn spec_rejects_malformed() {
        assert!(AttnKernelSpec::parse("model_s1_hfa").is_none());
        assert!(AttnKernelSpec::parse("attn_hfa_dxx_n1024_b16").is_none());
    }
}

//! PJRT CPU client wrapper: HLO text -> compile -> execute.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax >= 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! The real client needs the `xla` bindings crate, which is not on
//! crates.io — it is compiled in only under `--cfg hfa_pjrt` (see
//! Cargo.toml's check-cfg entry).  Without it this module presents the
//! same API but every entry point returns a clean "built without PJRT
//! support" error, so the coordinator, CLI and tests degrade gracefully
//! (they already skip when artifacts are unavailable).

#[cfg(not(hfa_pjrt))]
use anyhow::bail;
use anyhow::Result;
#[cfg(hfa_pjrt)]
use anyhow::Context;
use std::path::Path;

use crate::Mat;

/// Element type of an executable argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgType {
    F32,
    Bf16,
    I32,
}

/// The PJRT engine: one CPU client shared by all loaded executables.
pub struct Engine {
    #[cfg(hfa_pjrt)]
    client: xla::PjRtClient,
    #[cfg(not(hfa_pjrt))]
    _priv: (),
}

/// A compiled executable plus its expected input/output geometry.
pub struct LoadedExecutable {
    #[cfg(hfa_pjrt)]
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(hfa_pjrt)]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO text module.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedExecutable {
            exe,
            name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
        })
    }
}

/// Build an input literal from f32 data with the given logical shape,
/// converted to the executable's expected element type.
#[cfg(hfa_pjrt)]
pub fn literal_f32(data: &[f32], shape: &[i64], ty: ArgType) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data).reshape(shape)?;
    Ok(match ty {
        ArgType::F32 => lit,
        ArgType::Bf16 => lit.convert(xla::ElementType::Bf16.primitive_type())?,
        ArgType::I32 => lit.convert(xla::ElementType::S32.primitive_type())?,
    })
}

/// Build an i32 input literal.
#[cfg(hfa_pjrt)]
pub fn literal_i32(data: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

#[cfg(hfa_pjrt)]
impl LoadedExecutable {
    /// Execute with the given literals; returns the elements of the output
    /// tuple as f32 vectors (jax lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            let f = e.convert(xla::ElementType::F32.primitive_type())?;
            out.push(f.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Convenience: run an attention kernel `(q, k, v) -> o` where all
    /// tensors are BF16 on the wire and `Mat`-shaped on the rust side.
    pub fn run_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        let ql = literal_f32(&q.data, &[q.rows as i64, q.cols as i64], ArgType::Bf16)?;
        let kl = literal_f32(&k.data, &[k.rows as i64, k.cols as i64], ArgType::Bf16)?;
        let vl = literal_f32(&v.data, &[v.rows as i64, v.cols as i64], ArgType::Bf16)?;
        let outs = self.run(&[ql, kl, vl])?;
        anyhow::ensure!(outs.len() == 1, "expected a 1-tuple result");
        Ok(Mat::from_vec(q.rows, q.cols, outs.into_iter().next().unwrap()))
    }

    /// Convenience: run a full-model forward `tokens (1,T) -> logits
    /// (1,T,V)`; returns the flat logits vector.
    pub fn run_model(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let tl = literal_i32(tokens, &[1, tokens.len() as i64])?;
        let outs = self.run(&[tl])?;
        anyhow::ensure!(outs.len() == 1, "expected a 1-tuple result");
        Ok(outs.into_iter().next().unwrap())
    }
}

#[cfg(not(hfa_pjrt))]
const NO_PJRT: &str =
    "built without PJRT support (compile with --cfg hfa_pjrt and the xla bindings crate)";

#[cfg(not(hfa_pjrt))]
impl Engine {
    pub fn cpu() -> Result<Engine> {
        bail!(NO_PJRT)
    }

    pub fn platform(&self) -> String {
        "none".into()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedExecutable> {
        bail!(NO_PJRT)
    }
}

#[cfg(not(hfa_pjrt))]
impl LoadedExecutable {
    pub fn run_attention(&self, _q: &Mat, _k: &Mat, _v: &Mat) -> Result<Mat> {
        bail!(NO_PJRT)
    }

    pub fn run_model(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

//! Persistent scoped worker pool for the serving hot path.
//!
//! The seed implementation fanned every `partial_states` call out with
//! `std::thread::scope`, paying an OS thread spawn + join per attention
//! call — measurable at coordinator batch rates (EXPERIMENTS.md §Perf).
//! This pool spawns its workers once and hands them borrowed jobs through
//! a shared queue; `run_scoped` blocks until every submitted job has
//! completed, which is what makes lifetime erasure of the borrows sound.
//!
//! Design notes:
//! * The caller *helps*: after enqueueing it drains the queue itself until
//!   empty, then waits on a completion latch.  A pool whose worker spawns
//!   all failed therefore still makes progress (serial execution), and
//!   nested `run_scoped` calls from inside a pool job cannot deadlock.
//! * Panics inside a job are caught so the latch always resolves; the
//!   panic is re-raised on the submitting thread.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::sync::{thread, Arc, Condvar, Mutex, OnceLock};

/// Payload of a panicked job, kept so the submitter can re-raise it.
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// A lifetime-erased job. Only constructed inside `run_scoped`, which
/// guarantees the borrows outlive execution by blocking until completion.
type Task = Box<dyn FnOnce() + Send + 'static>;

struct QueueInner {
    tasks: VecDeque<Task>,
    open: bool,
}

struct Queue {
    inner: Mutex<QueueInner>,
    available: Condvar,
}

/// Completion latch for one `run_scoped` call.
struct Latch {
    /// (jobs remaining, first panic payload if any)
    state: Mutex<(usize, Option<PanicPayload>)>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, None)), done: Condvar::new() }
    }

    fn complete(&self, panicked: Option<PanicPayload>) {
        let mut g = self.state.lock();
        g.0 -= 1;
        if g.1.is_none() {
            g.1 = panicked;
        }
        if g.0 == 0 {
            self.done.notify_all();
        }
    }

    /// All jobs of this latch completed (drained or executed elsewhere)?
    fn finished(&self) -> bool {
        self.state.lock().0 == 0
    }

    /// Block until all jobs completed; returns the first panic payload.
    fn wait(&self) -> Option<PanicPayload> {
        let mut g = self.state.lock();
        while g.0 > 0 {
            g = self.done.wait(g);
        }
        g.1.take()
    }
}

/// A persistent pool of worker threads executing borrowed, scoped jobs.
pub struct WorkerPool {
    queue: Arc<Queue>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (clamped to >= 0 spawned; the
    /// submitting thread always participates, so even 0 workers executes).
    pub fn new(threads: usize) -> WorkerPool {
        let queue = Arc::new(Queue {
            inner: Mutex::new(QueueInner { tasks: VecDeque::new(), open: true }),
            available: Condvar::new(),
        });
        let mut workers = 0;
        for i in 0..threads {
            let q = queue.clone();
            let spawned = thread::Builder::new()
                .name(format!("hfa-pool-{i}"))
                .spawn(move || worker_loop(q));
            if spawned.is_ok() {
                workers += 1;
            }
        }
        WorkerPool { queue, workers }
    }

    /// Parallel capacity: worker threads plus the submitting thread.
    pub fn parallelism(&self) -> usize {
        self.workers + 1
    }

    /// Execute all `jobs` (which may borrow from the caller's stack) and
    /// return once every one has finished.  Panics if any job panicked.
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        {
            let mut g = self.queue.inner.lock();
            for job in jobs {
                let l = latch.clone();
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(job));
                    l.complete(r.err());
                });
                // SAFETY: `run_scoped` does not return until the latch
                // reports every job complete, so the 'scope borrows inside
                // `wrapped` strictly outlive its execution.  The panic
                // guard above guarantees the latch always resolves.
                let task: Task = unsafe {
                    let raw: *mut (dyn FnOnce() + Send + 'scope) = Box::into_raw(wrapped);
                    let raw: *mut (dyn FnOnce() + Send + 'static) = std::mem::transmute(raw);
                    Box::from_raw(raw)
                };
                g.tasks.push_back(task);
            }
            self.queue.available.notify_all();
        }
        // Help drain the queue while waiting — keeps the submitting core
        // busy and makes the pool safe to re-enter from inside a job.
        // The finished-check runs before each pop, so helping stops at
        // the first opportunity after this call's jobs complete; a task
        // already started (possibly another caller's) still runs to
        // completion first, so the return can be delayed by at most one
        // foreign task's duration.
        while !latch.finished() {
            let task = self.queue.inner.lock().tasks.pop_front();
            match task {
                Some(t) => t(),
                None => break,
            }
        }
        // Re-raise the original panic payload (message, file, line intact)
        // on the submitting thread, matching std::thread::scope semantics.
        if let Some(payload) = latch.wait() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let mut g = self.queue.inner.lock();
        g.open = false;
        self.queue.available.notify_all();
    }
}

fn worker_loop(queue: Arc<Queue>) {
    loop {
        let task = {
            let mut g = queue.inner.lock();
            loop {
                if let Some(t) = g.tasks.pop_front() {
                    break Some(t);
                }
                if !g.open {
                    break None;
                }
                g = queue.available.wait(g);
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// Fan `run(0..n)` out over the global pool in contiguous chunks and
/// collect the results in index order.  Falls back to a plain serial
/// loop when `n <= 1` or no parallelism is available; results are
/// identical either way (each index is computed independently).
pub fn fan_out<T, F>(n: usize, run: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    fan_out_chunked(n, 1, run)
}

/// [`fan_out`] with a floor on items per job: when per-item work is
/// tiny (single queries, per-query merge chains), dispatching one boxed
/// job per item spends more on the queue round-trip than on the work —
/// this variant groups at least `min_per_job` consecutive indices into
/// each job.  Results are always returned in index order, and the
/// serial fallback computes identical values, so the chunking is
/// invisible to callers (pinned by `fan_out_chunked_preserves_index_order`).
pub fn fan_out_chunked<T, F>(n: usize, min_per_job: usize, run: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let pool = global();
    let width = pool.parallelism();
    // even split across the pool, then floored so no job is dispatched
    // for less than min_per_job items' worth of work
    let chunk = n.div_ceil(width.min(n.max(1))).max(min_per_job.max(1));
    if n > chunk && width > 1 {
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .chunks_mut(chunk)
            .enumerate()
            .map(|(t, out)| {
                let run = &run;
                Box::new(move || {
                    for (j, slot) in out.iter_mut().enumerate() {
                        *slot = Some(run(t * chunk + j));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        return slots
            .into_iter()
            .map(|s| s.expect("fan_out filled every slot"))
            .collect();
    }
    (0..n).map(|i| run(i)).collect()
}

/// The process-wide pool used by the attention hot path.  Sized to the
/// machine minus one (the submitting thread helps), spawned on first use,
/// never torn down.  `HFA_POOL_THREADS` overrides the worker count
/// (0 = no workers, every fan-out runs serially on the submitting
/// thread) — the knob behind EXPERIMENTS.md §Tiling's single-thread
/// tile-reuse measurement.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = std::env::var("HFA_POOL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or_else(|| {
                thread::available_parallelism().map(|n| n.get()).unwrap_or(1).saturating_sub(1)
            });
        WorkerPool::new(workers)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::counter::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_with_borrows() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 64];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(8)
                .enumerate()
                .map(|(c, chunk)| {
                    Box::new(move || {
                        for (j, slot) in chunk.iter_mut().enumerate() {
                            *slot = c * 8 + j + 1;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &x)| x == i + 1));
    }

    #[test]
    fn zero_worker_pool_degrades_to_serial() {
        let pool = WorkerPool::new(0);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..10)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn reentrant_from_inside_a_job_same_pool() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let (hits, pool) = (&hits, &pool);
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            Box::new(move || {
                                hits.fetch_add(1, Ordering::Relaxed);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn job_panic_propagates_with_original_payload() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|i| {
                Box::new(move || {
                    if i == 1 {
                        panic!("boom");
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
    }

    #[test]
    fn fan_out_chunked_preserves_index_order() {
        // whatever the chunking (parallel split, floored jobs, serial
        // fallback), result i must be run(i)
        for (n, min) in [(0usize, 4usize), (1, 4), (7, 1), (64, 8), (100, 3), (5, 100)] {
            let out = fan_out_chunked(n, min, |i| i * 3 + 1);
            let want: Vec<usize> = (0..n).map(|i| i * 3 + 1).collect();
            assert_eq!(out, want, "n={n} min_per_job={min}");
        }
        // and it computes exactly what plain fan_out computes
        let a = fan_out(33, |i| i as u64 * 7 + 2);
        let b = fan_out_chunked(33, 5, |i| i as u64 * 7 + 2);
        assert_eq!(a, b);
    }

    #[test]
    fn global_pool_usable_from_many_threads() {
        let done: Vec<_> = (0..4)
            .map(|t| {
                crate::sync::thread::spawn(move || {
                    let mut acc = vec![0u64; 32];
                    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = acc
                        .chunks_mut(8)
                        .map(|chunk| {
                            Box::new(move || {
                                for slot in chunk.iter_mut() {
                                    *slot = t as u64 + 1;
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    global().run_scoped(jobs);
                    acc.iter().sum::<u64>()
                })
            })
            .collect();
        for (t, h) in done.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), 32 * (t as u64 + 1));
        }
    }
}

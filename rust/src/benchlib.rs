//! Criterion-style benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9): warmup + timed iterations, robust statistics, and
//! markdown/CSV emitters used by every `rust/benches/*` table/figure
//! regenerator.

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = |q: f64| ns[((ns.len() as f64 - 1.0) * q) as usize];
        Stats {
            iters: ns.len(),
            mean_ns: ns.iter().sum::<f64>() / ns.len() as f64,
            p50_ns: idx(0.50),
            p99_ns: idx(0.99),
            min_ns: ns[0],
            max_ns: *ns.last().unwrap(),
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` with warmup; at most `max_iters` iterations or `budget` total.
pub fn bench<F: FnMut()>(warmup: usize, max_iters: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters);
    let start = Instant::now();
    for _ in 0..max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// A results table that renders to markdown and CSV.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}\n", self.title);
        let line = |cells: &[String], out: &mut String| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            out.push_str(&s);
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print markdown to stdout and persist CSV under
    /// `target/bench_results/<slug>.csv`.
    pub fn emit(&self, slug: &str) {
        print!("{}", self.to_markdown());
        let dir = results_dir();
        let _ = fs::create_dir_all(&dir);
        let path = dir.join(format!("{slug}.csv"));
        if fs::write(&path, self.to_csv()).is_ok() {
            println!("(csv: {})", path.display());
        }
    }
}

pub fn results_dir() -> PathBuf {
    PathBuf::from("target/bench_results")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!(s.p50_ns <= s.p99_ns);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut n = 0u64;
        let s = bench(2, 10, Duration::from_secs(5), || {
            n += 1;
        });
        assert!(s.iters > 0 && s.iters <= 10);
        assert!(n >= s.iters as u64);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}

//! Criterion-style benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9): warmup + timed iterations, robust statistics, and
//! markdown/CSV emitters used by every `rust/benches/*` table/figure
//! regenerator.

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = |q: f64| ns[((ns.len() as f64 - 1.0) * q) as usize];
        Stats {
            iters: ns.len(),
            mean_ns: ns.iter().sum::<f64>() / ns.len() as f64,
            p50_ns: idx(0.50),
            p99_ns: idx(0.99),
            min_ns: ns[0],
            max_ns: *ns.last().unwrap(),
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` with warmup; at most `max_iters` iterations or `budget` total.
pub fn bench<F: FnMut()>(warmup: usize, max_iters: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters);
    let start = Instant::now();
    for _ in 0..max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// A results table that renders to markdown and CSV.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}\n", self.title);
        let line = |cells: &[String], out: &mut String| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            out.push_str(&s);
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print markdown to stdout and persist CSV under
    /// `target/bench_results/<slug>.csv`.
    pub fn emit(&self, slug: &str) {
        print!("{}", self.to_markdown());
        let dir = results_dir();
        let _ = fs::create_dir_all(&dir);
        let path = dir.join(format!("{slug}.csv"));
        if fs::write(&path, self.to_csv()).is_ok() {
            println!("(csv: {})", path.display());
        }
    }
}

pub fn results_dir() -> PathBuf {
    PathBuf::from("target/bench_results")
}

// ---------------------------------------------------------------------------
// Machine-readable perf rows (BENCH_attention.json)
// ---------------------------------------------------------------------------

/// One machine-readable perf measurement: the row schema of
/// `BENCH_attention.json` (`{bench, shape, ns_per_step, kv_bytes_copied}`),
/// emitted by `benches/e2e_throughput.rs` so the perf trajectory is
/// diffable by tooling instead of living only in markdown tables.
/// `kv_bytes_copied` carries whichever exact byte counter the row is
/// about (prepared-KV write traffic or kernel stream traffic); rows
/// with no byte dimension set it to 0.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub bench: String,
    pub shape: String,
    pub ns_per_step: f64,
    pub kv_bytes_copied: u64,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render perf rows as a JSON array (one object per row, fixed schema).
/// Non-finite timings are clamped to 0 so the output always parses.
pub fn bench_rows_to_json(rows: &[BenchRow]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        let ns = if r.ns_per_step.is_finite() { r.ns_per_step } else { 0.0 };
        let _ = write!(
            out,
            "  {{\"bench\": \"{}\", \"shape\": \"{}\", \"ns_per_step\": {}, \"kv_bytes_copied\": {}}}",
            json_escape(&r.bench),
            json_escape(&r.shape),
            ns,
            r.kv_bytes_copied
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    out
}

/// Write perf rows as `file` under [`results_dir`] and return the path.
pub fn write_bench_json(file: &str, rows: &[BenchRow]) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    fs::create_dir_all(&dir)?;
    let path = dir.join(file);
    fs::write(&path, bench_rows_to_json(rows))?;
    Ok(path)
}

/// Minimal JSON well-formedness validator (no serde in this offline
/// environment — DESIGN.md §9): objects, arrays, strings with escapes,
/// numbers, `true`/`false`/`null`.  Returns the byte offset of the
/// first violation.  The bench calls it on its own output so a broken
/// writer fails the CI perf-gate smoke instead of silently emitting an
/// unparseable trajectory file.
pub fn validate_json(s: &str) -> Result<(), String> {
    struct P<'a> {
        b: &'a [u8],
        i: usize,
    }
    impl P<'_> {
        fn ws(&mut self) {
            while matches!(self.b.get(self.i).copied(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.i += 1;
            }
        }

        fn err<T>(&self, m: &str) -> Result<T, String> {
            Err(format!("{m} at byte {}", self.i))
        }

        fn lit(&mut self, w: &str) -> Result<(), String> {
            if self.b[self.i..].starts_with(w.as_bytes()) {
                self.i += w.len();
                Ok(())
            } else {
                self.err("bad literal")
            }
        }

        fn string(&mut self) -> Result<(), String> {
            self.i += 1; // opening quote, checked by the caller
            while let Some(&c) = self.b.get(self.i) {
                match c {
                    b'"' => {
                        self.i += 1;
                        return Ok(());
                    }
                    b'\\' => self.i += 2,
                    _ => self.i += 1,
                }
            }
            self.err("unterminated string")
        }

        fn digits(&mut self) -> bool {
            let start = self.i;
            while self.b.get(self.i).is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
            self.i > start
        }

        fn number(&mut self) -> Result<(), String> {
            if self.b.get(self.i) == Some(&b'-') {
                self.i += 1;
            }
            if !self.digits() {
                return self.err("expected digits");
            }
            if self.b.get(self.i) == Some(&b'.') {
                self.i += 1;
                if !self.digits() {
                    return self.err("expected fraction digits");
                }
            }
            if matches!(self.b.get(self.i).copied(), Some(b'e' | b'E')) {
                self.i += 1;
                if matches!(self.b.get(self.i).copied(), Some(b'+' | b'-')) {
                    self.i += 1;
                }
                if !self.digits() {
                    return self.err("expected exponent digits");
                }
            }
            Ok(())
        }

        fn seq(&mut self, close: u8, item: fn(&mut Self) -> Result<(), String>) -> Result<(), String> {
            self.i += 1; // opening bracket, checked by the caller
            self.ws();
            if self.b.get(self.i) == Some(&close) {
                self.i += 1;
                return Ok(());
            }
            loop {
                item(self)?;
                self.ws();
                match self.b.get(self.i).copied() {
                    Some(b',') => {
                        self.i += 1;
                        self.ws();
                    }
                    Some(c) if c == close => {
                        self.i += 1;
                        return Ok(());
                    }
                    _ => return self.err("expected ',' or closer"),
                }
            }
        }

        fn member(&mut self) -> Result<(), String> {
            if self.b.get(self.i) != Some(&b'"') {
                return self.err("expected member key");
            }
            self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return self.err("expected ':'");
            }
            self.i += 1;
            self.value()
        }

        fn value(&mut self) -> Result<(), String> {
            self.ws();
            match self.b.get(self.i).copied() {
                Some(b'{') => self.seq(b'}', Self::member),
                Some(b'[') => self.seq(b']', Self::value),
                Some(b'"') => self.string(),
                Some(b't') => self.lit("true"),
                Some(b'f') => self.lit("false"),
                Some(b'n') => self.lit("null"),
                Some(c) if c.is_ascii_digit() || c == b'-' => self.number(),
                _ => self.err("expected value"),
            }
        }
    }
    let mut p = P { b: s.as_bytes(), i: 0 };
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Schema validation for BENCH_*.json trajectory files
// ---------------------------------------------------------------------------

/// A parsed JSON value — just enough structure for schema checks over
/// the small trajectory files (no serde offline; see DESIGN.md §9).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

/// Parse `s` into a [`JsonValue`].  Built on the same grammar as
/// [`validate_json`]; errors carry the byte offset of the violation.
pub fn parse_json(s: &str) -> Result<JsonValue, String> {
    validate_json(s)?; // single error surface for malformed input
    struct P<'a> {
        b: &'a [u8],
        i: usize,
    }
    impl P<'_> {
        fn ws(&mut self) {
            while matches!(self.b.get(self.i).copied(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.i += 1;
            }
        }

        fn string(&mut self) -> String {
            self.i += 1; // opening quote
            let mut out = String::new();
            loop {
                match self.b[self.i] {
                    b'"' => {
                        self.i += 1;
                        return out;
                    }
                    b'\\' => {
                        let esc = self.b[self.i + 1];
                        self.i += 2;
                        match esc {
                            b'"' => out.push('"'),
                            b'\\' => out.push('\\'),
                            b'/' => out.push('/'),
                            b'n' => out.push('\n'),
                            b't' => out.push('\t'),
                            b'r' => out.push('\r'),
                            b'u' => {
                                let hex =
                                    std::str::from_utf8(&self.b[self.i..self.i + 4]).unwrap_or("");
                                self.i += 4;
                                if let Ok(cp) = u32::from_str_radix(hex, 16) {
                                    out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                }
                            }
                            c => out.push(c as char),
                        }
                    }
                    _ => {
                        // validate_json guaranteed well-formed UTF-8 input;
                        // copy the raw char
                        let rest = std::str::from_utf8(&self.b[self.i..]).unwrap_or("");
                        let c = rest.chars().next().unwrap_or('\u{fffd}');
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn value(&mut self) -> JsonValue {
            self.ws();
            match self.b[self.i] {
                b'{' => {
                    self.i += 1;
                    self.ws();
                    let mut members = Vec::new();
                    if self.b[self.i] == b'}' {
                        self.i += 1;
                        return JsonValue::Object(members);
                    }
                    loop {
                        self.ws();
                        let key = self.string();
                        self.ws();
                        self.i += 1; // ':'
                        let v = self.value();
                        members.push((key, v));
                        self.ws();
                        if self.b[self.i] == b',' {
                            self.i += 1;
                        } else {
                            self.i += 1; // '}'
                            return JsonValue::Object(members);
                        }
                    }
                }
                b'[' => {
                    self.i += 1;
                    self.ws();
                    let mut items = Vec::new();
                    if self.b[self.i] == b']' {
                        self.i += 1;
                        return JsonValue::Array(items);
                    }
                    loop {
                        items.push(self.value());
                        self.ws();
                        if self.b[self.i] == b',' {
                            self.i += 1;
                        } else {
                            self.i += 1; // ']'
                            return JsonValue::Array(items);
                        }
                    }
                }
                b'"' => JsonValue::String(self.string()),
                b't' => {
                    self.i += 4;
                    JsonValue::Bool(true)
                }
                b'f' => {
                    self.i += 5;
                    JsonValue::Bool(false)
                }
                b'n' => {
                    self.i += 4;
                    JsonValue::Null
                }
                _ => {
                    let start = self.i;
                    while self
                        .b
                        .get(self.i)
                        .is_some_and(|&c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
                    {
                        self.i += 1;
                    }
                    let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap_or("0");
                    JsonValue::Number(txt.parse().unwrap_or(0.0))
                }
            }
        }
    }
    let mut p = P { b: s.as_bytes(), i: 0 };
    Ok(p.value())
}

/// Validate that `s` is a benchlib trajectory file: a JSON array whose
/// every element is an object with **exactly** the [`BenchRow`] fields —
/// `bench` (string), `shape` (string), `ns_per_step` (finite number
/// >= 0), `kv_bytes_copied` (non-negative integer).  Returns the row
/// count; the committed placeholder `[]` validates as 0 rows.  Run by
/// CI over `BENCH_serving.json` so a writer drift (renamed field, NaN
/// timing, stray key) fails the gate instead of silently producing an
/// untoolable trajectory.
pub fn validate_bench_schema(s: &str) -> Result<usize, String> {
    let rows = match parse_json(s)? {
        JsonValue::Array(rows) => rows,
        _ => return Err("top-level value must be an array of bench rows".into()),
    };
    for (i, row) in rows.iter().enumerate() {
        let members = match row {
            JsonValue::Object(m) => m,
            _ => return Err(format!("row {i}: expected an object")),
        };
        let mut keys: Vec<&str> = members.iter().map(|(k, _)| k.as_str()).collect();
        keys.sort_unstable();
        if keys != ["bench", "kv_bytes_copied", "ns_per_step", "shape"] {
            return Err(format!(
                "row {i}: expected exactly {{bench, shape, ns_per_step, kv_bytes_copied}}, got {{{}}}",
                keys.join(", ")
            ));
        }
        for (key, val) in members {
            match (key.as_str(), val) {
                ("bench" | "shape", JsonValue::String(v)) => {
                    if v.is_empty() {
                        return Err(format!("row {i}: {key} must be non-empty"));
                    }
                }
                ("bench" | "shape", _) => {
                    return Err(format!("row {i}: {key} must be a string"));
                }
                ("ns_per_step", JsonValue::Number(v)) => {
                    if !v.is_finite() || *v < 0.0 {
                        return Err(format!("row {i}: ns_per_step must be finite and >= 0"));
                    }
                }
                ("ns_per_step", _) => {
                    return Err(format!("row {i}: ns_per_step must be a number"));
                }
                ("kv_bytes_copied", JsonValue::Number(v)) => {
                    if *v < 0.0 || v.fract() != 0.0 {
                        return Err(format!(
                            "row {i}: kv_bytes_copied must be a non-negative integer"
                        ));
                    }
                }
                ("kv_bytes_copied", _) => {
                    return Err(format!("row {i}: kv_bytes_copied must be a number"));
                }
                _ => unreachable!("key set checked above"),
            }
        }
    }
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!(s.p50_ns <= s.p99_ns);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut n = 0u64;
        let s = bench(2, 10, Duration::from_secs(5), || {
            n += 1;
        });
        assert!(s.iters > 0 && s.iters <= 10);
        assert!(n >= s.iters as u64);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn bench_rows_roundtrip_through_the_validator() {
        let rows = vec![
            BenchRow {
                bench: "kernel_stream_qt8".into(),
                shape: "B16_N1024_d64_p1".into(),
                ns_per_step: 12345.678,
                kv_bytes_copied: 8_650_752,
            },
            BenchRow {
                bench: "decode \"quoted\\name\"".into(), // escapes survive
                shape: "B1_N1024_d64_p8".into(),
                ns_per_step: f64::NAN, // clamped, must still parse
                kv_bytes_copied: 0,
            },
        ];
        let json = bench_rows_to_json(&rows);
        validate_json(&json).expect("emitted rows must be valid JSON");
        assert!(json.contains("\"ns_per_step\": 12345.678"));
        assert!(json.contains("\"kv_bytes_copied\": 8650752"));
        assert!(json.contains("\\\"quoted\\\\name\\\""));
        // empty row set is a valid (empty) array
        validate_json(&bench_rows_to_json(&[])).expect("empty array");
    }

    #[test]
    fn parse_json_builds_values() {
        let v = parse_json("[{\"a\": 1.5, \"b\": \"x\\ny\"}, true, null, -3]").unwrap();
        let JsonValue::Array(items) = v else { panic!("expected array") };
        assert_eq!(items.len(), 4);
        assert_eq!(
            items[0],
            JsonValue::Object(vec![
                ("a".into(), JsonValue::Number(1.5)),
                ("b".into(), JsonValue::String("x\ny".into())),
            ])
        );
        assert_eq!(items[1], JsonValue::Bool(true));
        assert_eq!(items[2], JsonValue::Null);
        assert_eq!(items[3], JsonValue::Number(-3.0));
        assert!(parse_json("{nope").is_err());
    }

    #[test]
    fn bench_schema_accepts_real_rows_and_the_placeholder() {
        assert_eq!(validate_bench_schema("[]").unwrap(), 0, "committed placeholder");
        let rows = vec![BenchRow {
            bench: "serving_soak".into(),
            shape: "S64_d8".into(),
            ns_per_step: 123.0,
            kv_bytes_copied: 4096,
        }];
        assert_eq!(validate_bench_schema(&bench_rows_to_json(&rows)).unwrap(), 1);
    }

    #[test]
    fn bench_schema_rejects_drifted_rows() {
        for (bad, why) in [
            ("{}", "top-level object"),
            ("[1]", "non-object row"),
            ("[{\"bench\": \"b\", \"shape\": \"s\", \"ns_per_step\": 1}]", "missing field"),
            (
                "[{\"bench\": \"b\", \"shape\": \"s\", \"ns_per_step\": 1, \"kv_bytes_copied\": 0, \"extra\": 1}]",
                "stray field",
            ),
            (
                "[{\"bench\": \"\", \"shape\": \"s\", \"ns_per_step\": 1, \"kv_bytes_copied\": 0}]",
                "empty bench name",
            ),
            (
                "[{\"bench\": \"b\", \"shape\": \"s\", \"ns_per_step\": -1, \"kv_bytes_copied\": 0}]",
                "negative timing",
            ),
            (
                "[{\"bench\": \"b\", \"shape\": \"s\", \"ns_per_step\": 1, \"kv_bytes_copied\": 0.5}]",
                "fractional bytes",
            ),
            (
                "[{\"bench\": \"b\", \"shape\": \"s\", \"ns_per_step\": \"fast\", \"kv_bytes_copied\": 0}]",
                "string timing",
            ),
        ] {
            assert!(validate_bench_schema(bad).is_err(), "{why} must be rejected");
        }
    }

    #[test]
    fn validator_accepts_json_and_rejects_garbage() {
        for ok in [
            "[]",
            "{}",
            "  [ {\"a\": 1, \"b\": [true, false, null]}, -2.5e-3 ]  ",
            "\"str with \\\" escape\"",
            "-0.5",
        ] {
            validate_json(ok).unwrap_or_else(|e| panic!("{ok:?} rejected: {e}"));
        }
        for bad in [
            "",
            "[1,]",
            "{\"a\" 1}",
            "{\"a\": }",
            "[1} ",
            "\"unterminated",
            "01x",
            "[1] trailing",
            "1.",
            "nul",
        ] {
            assert!(validate_json(bad).is_err(), "{bad:?} accepted");
        }
    }
}

//! Concurrency facade: every module in this crate imports its
//! synchronization primitives from here, never from `std::sync` /
//! `std::thread` directly (enforced by `cargo run -p xtask -- lint`).
//!
//! Under a normal build the facade is a thin veneer over `std`.  Under
//! `RUSTFLAGS="--cfg loom"` the *modeled* primitives — [`Mutex`],
//! [`Condvar`], and the [`atomic`] module — switch to their
//! [`loom`](https://docs.rs/loom) equivalents, so the protocol structs
//! built from them ([`crate::coordinator::protocol`], the worker pool)
//! can be exhaustively model-checked by `rust/tests/loom_models.rs`
//! across every bounded-preemption interleaving, not just the ones a
//! lucky CI run happens to schedule.
//!
//! What intentionally stays `std` under **both** cfgs:
//!
//! * [`Arc`] — used throughout for immutable snapshot sharing (prepared
//!   KV chunk tables, backend caches), not as a protocol under test;
//!   the copy-on-write append path also needs `Arc::make_mut` /
//!   `Arc::strong_count`, which loom's `Arc` does not provide.  Loom
//!   models that want modeled reference counting use `loom::sync::Arc`
//!   directly in the test harness.
//! * [`mpsc`] — loom's channel shim lacks `sync_channel` /
//!   `recv_timeout`, which the ingress path is built on.  Channels are
//!   exercised by the chaos soak + TSan lane instead; the loom suite
//!   models the hand-rolled protocols (queue, guards, registry, gate)
//!   that channels cannot express.
//! * [`thread`] and [`OnceLock`] — thread *creation* is never performed
//!   inside a loom model (models spawn `loom::thread` directly); the
//!   server/pool spawning paths need `Builder`, `available_parallelism`
//!   and `sleep`, none of which loom models.
//! * [`counter`] — always-`std` atomics for `static` process-wide
//!   counters (traffic/telemetry).  Loom atomics cannot live in a
//!   `static` (no `const fn new`, and statics outlive any single model
//!   execution), so these are declared unmodeled by construction.
//!
//! Poison handling: [`Mutex::lock`] and [`Condvar::wait`] are
//! **infallible** — a poisoned lock hands back the inner guard instead
//! of an `Err`.  Every critical section in this crate leaves its data
//! structurally valid at each await/unlock point (documented per call
//! site), and the serving loop's panic guards (`PinGuard`, `WorkerExit`,
//! `CloseOnExit`) run in `Drop` during unwinds, where a poison
//! `unwrap()` would escalate a caught backend panic into a double-panic
//! abort of the whole process.

/// Loom-aware atomics: `std::sync::atomic` normally, `loom`'s under
/// `--cfg loom`.  Every non-`static` atomic in the crate comes from
/// here so the loom suite can model it.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

/// Always-`std` atomics for `static` process-wide counters (KV traffic
/// meters, log level).  Statics outlive any loom execution and loom's
/// atomics have no `const fn new`, so these sites are explicitly
/// *unmodeled*; they carry telemetry, never synchronization (each is
/// documented `// ordering: Relaxed` at the use site, with thread
/// `join()` providing the happens-before edge for tests that read them).
pub mod counter {
    pub use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
}

/// Snapshot-sharing `Arc` (std under both cfgs — see module docs).
pub use std::sync::Arc;

/// Ingress/reply channels (std under both cfgs — see module docs).
pub use std::sync::mpsc;

/// One-time initialization for process-wide singletons (std under both
/// cfgs; never touched inside a loom model).
pub use std::sync::OnceLock;

/// Thread spawning/sleeping (std under both cfgs — see module docs).
/// Loom models never call these; they spawn `loom::thread` themselves.
pub mod thread {
    pub use std::thread::{available_parallelism, sleep, spawn, Builder, JoinHandle};
}

#[cfg(not(loom))]
use std::sync as imp;

#[cfg(loom)]
use loom::sync as imp;

/// Guard type returned by [`Mutex::lock`] / threaded through
/// [`Condvar::wait`].
pub type MutexGuard<'a, T> = imp::MutexGuard<'a, T>;

/// Mutual exclusion with an **infallible** `lock()` (poison recovery —
/// see module docs).  Backed by `loom::sync::Mutex` under `--cfg loom`.
pub struct Mutex<T>(imp::Mutex<T>);

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex(imp::Mutex::new(t))
    }

    /// Acquire the lock, recovering the guard from a poisoned mutex (a
    /// panicked holder): critical sections in this crate keep their data
    /// valid at every unlock point, and the serving loop's `Drop` guards
    /// must not double-panic during an unwind.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.0.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

/// Condition variable whose `wait` is infallible under poisoning, to
/// match [`Mutex::lock`].  Backed by `loom::sync::Condvar` under
/// `--cfg loom`.
pub struct Condvar(imp::Condvar);

impl Condvar {
    pub fn new() -> Condvar {
        Condvar(imp::Condvar::new())
    }

    /// Release the guard's lock, park until notified, re-acquire.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.0.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Timed wait: park until notified or `dur` elapses; the bool is
    /// whether the wait ended by timeout.  Under `--cfg loom` this
    /// degrades to an untimed [`Condvar::wait`] reporting `false` — loom
    /// has no time model, and the protocols that lean on the timeout
    /// (the ingress write queue's stall budget) are exercised by the
    /// chaos soak + TSan lane, not the loom suite.
    #[cfg(not(loom))]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let (g, res) =
            self.0.wait_timeout(guard, dur).unwrap_or_else(|poisoned| poisoned.into_inner());
        (g, res.timed_out())
    }

    /// Loom stand-in for the timed wait (see the `cfg(not(loom))` docs).
    #[cfg(loom)]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        (self.wait(guard), false)
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        // poison the mutex by panicking while holding it
        let _ = thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison");
        })
        .join();
        // an infallible lock still hands the data back
        assert_eq!(*m.lock(), 7);
        *m.lock() = 9;
        assert_eq!(*m.lock(), 9);
    }

    #[test]
    fn condvar_roundtrip() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = pair.clone();
        let h = thread::spawn(move || {
            let (lock, cv) = &*p2;
            *lock.lock() = true;
            cv.notify_one();
        });
        let (lock, cv) = &*pair;
        let mut g = lock.lock();
        while !*g {
            g = cv.wait(g);
        }
        drop(g);
        h.join().expect("signaller exits cleanly");
    }
}

//! # H-FA: hybrid floating-point / logarithmic FlashAttention accelerator
//!
//! Full-system reproduction of *"H-FA: A Hybrid Floating-Point and
//! Logarithmic Approach to Hardware Accelerated FlashAttention"*
//! (Alexandridis & Dimitrakopoulos, CS.AR 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer rust+JAX+Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`arith`] — bit-accurate software models of the hardware number
//!   formats: BFloat16, Q9.7 fixed point, the logarithmic number system
//!   (LNS) with Mitchell's approximation and the 8-segment PWL `2^-f`.
//! * [`attention`] — algorithm-level golden models: exact softmax, lazy
//!   softmax (Alg. 1), FlashAttention-2 (Alg. 2), the H-FA log-domain
//!   datapath (Eqs. 14-19, bit-exact vs. the python spec), and the
//!   multi-block merge (Eqs. 1/16).
//! * [`hw`] — RTL-equivalent cycle simulator of the parallel accelerator
//!   (FAUs, ACC cascade, DIV/LogDiv, ready/valid pipeline; Figs. 1-4) and
//!   the 28 nm area/power cost model that regenerates Figs. 6-8, Table IV.
//! * [`runtime`] — PJRT bridge: loads the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` and executes them on CPU.
//! * [`coordinator`] — the serving stack: request router, dynamic batcher,
//!   KV-buffer manager, FAU scheduler, metrics.
//! * [`model`] / [`evalsuite`] — native tiny-LM inference engine and the
//!   synthetic benchmark suite backing the Table I/II/III accuracy study.
//!
//! Support substrates built in-repo (offline environment, see DESIGN.md §9):
//! [`proptest`] (property testing), [`benchlib`] (criterion-style bench
//! harness), [`cli`] (argument parsing), [`golden`] (golden-vector replay).
//!
//! All concurrency primitives are imported through the [`sync`] facade
//! (std normally, loom under `--cfg loom`) so the protocols in
//! [`coordinator::protocol`] can be exhaustively model-checked; see
//! `rust/EXPERIMENTS.md` §Verification.

pub mod arith;
pub mod attention;
pub mod benchlib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod evalsuite;
pub mod golden;
pub mod hw;
pub mod logging;
pub mod model;
pub mod proptest;
pub mod runtime;
pub mod sync;
pub mod tensor;

pub use arith::bf16::Bf16;
pub use arith::lns::Lns;
pub use tensor::Mat;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$HFA_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walking up from the current dir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("HFA_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (first token NOT included).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects a number, got {v:?}"),
            },
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        // note: a bare `--flag positional` is ambiguous (the token after a
        // `--name` is consumed as its value); flags go last or use `=`.
        let a = parse("serve pos1 --model s1 --impl=hfa --verbose");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.get("model"), Some("s1"));
        assert_eq!(a.get("impl"), Some("hfa"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag_not_eaten() {
        let a = parse("--fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn numeric_accessors() {
        let a = parse("--n 1024 --scale 0.125");
        assert_eq!(a.get_usize("n", 1).unwrap(), 1024);
        assert_eq!(a.get_f64("scale", 0.0).unwrap(), 0.125);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse("--n abc").get_usize("n", 1).is_err());
    }

    #[test]
    fn list_accessor() {
        let a = parse("--sizes s0,s1,s2");
        assert_eq!(a.get_list("sizes", &[]), vec!["s0", "s1", "s2"]);
        assert_eq!(a.get_list("other", &["x"]), vec!["x"]);
    }
}

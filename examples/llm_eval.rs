//! Whole-system LLM evaluation driver — the paper's Section VI-A workflow:
//! load the tiny LM trained at artifact-build time, run the synthetic
//! benchmark suite with FA-2 vs H-FA attention (native engine), measure
//! accuracy deltas and logit error, and cross-check the native engine
//! against the AOT-compiled PJRT full-model artifact.
//!
//!     cargo run --release --example llm_eval [-- --size s1 --limit 50]

use hfa::arith::mitchell::MitchellHistogram;
use hfa::cli::Args;
use hfa::evalsuite::score::{evaluate_file, mean_logit_error};
use hfa::evalsuite::tasks::list_eval_files;
use hfa::model::{AttnSelect, Transformer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let size = args.get_or("size", "s1");
    let limit = args.get_usize("limit", 50)?;
    let artifacts = hfa::artifacts_dir();

    let model = Transformer::load(&artifacts.join("models").join(size))?;
    println!(
        "loaded {size}: d_model={} heads={} layers={} (trained at artifact build)",
        model.cfg.d_model, model.cfg.n_head, model.cfg.n_layer
    );

    // 1) accuracy: FA-2 vs H-FA across the benchmark suite
    let files = list_eval_files(&artifacts.join("eval"))?;
    let mut hist = MitchellHistogram::new(32);
    println!("\ntask accuracy ({limit} instances each):");
    let mut worst_delta = 0.0f64;
    for (fam, var, path) in &files {
        let fa2 = evaluate_file(&model, path, AttnSelect::Fa2, limit, &mut None)?;
        let hfa = evaluate_file(&model, path, AttnSelect::Hfa, limit, &mut Some(&mut hist))?;
        let delta = hfa.pct() - fa2.pct();
        worst_delta = worst_delta.max(delta.abs());
        println!("  {fam}_{var:<3} H-FA {:5.1}%   FA-2 {:5.1}%   d {delta:+.1}", hfa.pct(), fa2.pct());
    }
    println!("worst |accuracy delta| = {worst_delta:.1} pts (paper: <= 4-5 on nearly all)");

    // 2) where the error comes from (Table III in miniature)
    let probe = artifacts.join("eval").join("assoc_2.txt");
    let all = hfa::attention::hfa::EmuConfig::all_on();
    let e_all = mean_logit_error(&model, &probe, AttnSelect::HfaEmu(all), 6)?;
    let e_nomit = mean_logit_error(
        &model,
        &probe,
        AttnSelect::HfaEmu(hfa::attention::hfa::EmuConfig { mitchell: false, ..all }),
        6,
    )?;
    println!(
        "\nlogit error (assoc_2): all approximations {:.4}; without Mitchell {:.4} -> Mitchell contributes {:.0}%",
        e_all,
        e_nomit,
        100.0 * (e_all - e_nomit).max(0.0) / e_all
    );

    // 3) Fig. 5 signal from live traffic
    println!(
        "Mitchell inputs recorded: {}; mass below 0.1: {:.0}%, below 0.5: {:.0}%",
        hist.total,
        100.0 * hist.mass_below(0.1),
        100.0 * hist.mass_below(0.5)
    );

    // 4) cross-check the native engine against the PJRT artifact
    match hfa::runtime::ArtifactRegistry::open(&artifacts)
        .and_then(|reg| reg.model(size, "exact").map(|e| (reg, e)))
    {
        Err(e) => println!("\n(PJRT cross-check skipped: {e})"),
        Ok((_reg, exe)) => {
            let tokens: Vec<i32> = (0..model.cfg.seq_len as i32).map(|i| (i * 5) % 60 + 4).collect();
            let native = model.forward(&tokens, AttnSelect::Exact, &mut None)?;
            let pjrt = exe.run_model(&tokens)?;
            let mut worst = 0.0f32;
            for (a, b) in native.data.iter().zip(&pjrt) {
                worst = worst.max((a - b).abs());
            }
            println!(
                "\nPJRT cross-check ({size}, exact attention): max |native - XLA| logit diff = {worst:.2e}"
            );
        }
    }
    Ok(())
}

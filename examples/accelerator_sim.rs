//! Hardware-architect view: sweep the accelerator design space with the
//! cycle simulator + cost model and print the trade-off table — the
//! exploration behind Figs. 7/8.
//!
//!     cargo run --release --example accelerator_sim [-- --head-dim 64]

use hfa::benchlib::Table;
use hfa::cli::Args;
use hfa::config::AcceleratorConfig;
use hfa::hw::cost::{report, Arith};
use hfa::hw::pipeline::{simulate, LatencyModel};
use hfa::hw::Accelerator;
use hfa::proptest::Rng;
use hfa::Mat;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let d = args.get_usize("head-dim", 64)?;
    let n = args.get_usize("seq-len", 1024)?;

    let mut t = Table::new(
        &format!("design-space sweep (d={d}, N={n}, one query datapath)"),
        &["arith", "p", "cycles/query-round", "time us", "area mm^2", "power mW",
          "edp (uJ*us)"],
    );
    let lat = LatencyModel::for_head_dim(d);
    for arith in [Arith::Fa2, Arith::Hfa] {
        for p in [1usize, 2, 4, 8] {
            let cfg = AcceleratorConfig {
                head_dim: d,
                seq_len: n,
                kv_blocks: p,
                parallel_queries: 1,
                freq_mhz: 500.0,
            };
            let s = simulate(d, n, p, 1, 1, lat);
            let r = report(arith, &cfg, 16);
            let time_us = s.time_us(500.0);
            let energy_uj = r.total_power_mw() * time_us / 1e3 / 1e3 * 1e3; // mW*us -> nJ -> uJ
            t.row(&[
                arith.name().into(),
                p.to_string(),
                s.cycles.to_string(),
                format!("{time_us:.2}"),
                format!("{:.3}", r.total_area_mm2()),
                format!("{:.0}", r.total_power_mw()),
                format!("{:.4}", energy_uj * time_us),
            ]);
        }
    }
    t.emit("accelerator_sim_sweep");

    // functional check on the chosen design point: run real data through
    // the RTL-equivalent model and compare H-FA vs FA-2 outputs
    let cfg = AcceleratorConfig {
        head_dim: d,
        seq_len: n,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let mut rng = Rng::new(11);
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d));
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d));
    let q = Mat::from_vec(4, d, rng.normal_vec(4 * d));
    let mut hfa_acc = Accelerator::new(Arith::Hfa, cfg.clone());
    let mut fa2_acc = Accelerator::new(Arith::Fa2, cfg);
    hfa_acc.load_kv(k.clone(), v.clone())?;
    fa2_acc.load_kv(k, v)?;
    let (oh, sh) = hfa_acc.compute_batch(&q)?;
    let (of, sf) = fa2_acc.compute_batch(&q)?;
    println!(
        "\nfunctional run: 4 queries, {} cycles each design (identical latency — paper Section VI-C)",
        sh.cycles
    );
    assert_eq!(sh.cycles, sf.cycles);
    println!("max |H-FA - FA-2| over outputs: {:.4}", oh.max_abs_diff(&of));
    Ok(())
}

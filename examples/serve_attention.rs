//! End-to-end serving driver: the coordinator (router + dynamic batcher +
//! KV store + workers) serving batched attention requests against multiple
//! KV sessions, backed by either the RTL-equivalent simulated accelerator
//! or the AOT-compiled PJRT H-FA kernel.  Reports latency percentiles and
//! throughput — the full L3 system on a real workload.
//!
//!     cargo run --release --example serve_attention [-- --pjrt]

use std::sync::Arc;
use std::time::{Duration, Instant};

use hfa::cli::Args;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{BackendFactory, KvStore, PjrtBackend, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::runtime::AttnKernelSpec;
use hfa::Mat;

const D: usize = 64;
const N: usize = 1024;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 512)?;
    let sessions = args.get_usize("sessions", 3)?;
    let workers = args.get_usize("workers", 2)?;

    let accel_cfg = AcceleratorConfig {
        head_dim: D,
        seq_len: N,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: 16,
        max_total_batch: 256,
        batch_window_us: 200,
        workers,
        queue_depth: 256,
        ..CoordinatorConfig::default()
    };

    // multiple resident KV sessions (different "documents"/heads)
    let mut rng = Rng::new(99);
    let kv = Arc::new(KvStore::new(N, D, sessions));
    let mut names = Vec::new();
    for s in 0..sessions {
        let name = format!("doc{s}");
        kv.put(&name, Mat::from_vec(N, D, rng.normal_vec(N * D)),
               Mat::from_vec(N, D, rng.normal_vec(N * D)))?;
        names.push(name);
    }
    println!(
        "KV store: {} sessions x {} kB BF16 (SRAM-modelled); byte budget {} kB, {} kB resident",
        sessions,
        kv.session_bytes() / 1024,
        kv.budget_bytes() / 1024,
        kv.used_bytes() / 1024
    );

    let use_pjrt = args.flag("pjrt");
    let factories: Vec<BackendFactory> = if use_pjrt {
        let spec = AttnKernelSpec { kind: "hfa".into(), head_dim: D, seq_len: N, batch: 16 };
        (0..workers).map(|_| PjrtBackend::factory(hfa::artifacts_dir(), spec.clone())).collect()
    } else {
        (0..workers).map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone())).collect()
    };
    let server = Server::start(&coord_cfg, kv, factories)?;
    println!(
        "coordinator up: {} workers ({}), max batch {}, window {} us",
        workers,
        if use_pjrt { "PJRT H-FA kernel" } else { "simulated H-FA accelerator" },
        coord_cfg.max_batch,
        coord_cfg.batch_window_us
    );

    // open-loop client: requests round-robin across sessions
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let session = &names[i % names.len()];
        loop {
            match server.submit(session, rng.normal_vec(D)) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(100)), // backpressure
            }
        }
    }
    let mut ok = 0;
    for rx in pending {
        let r = rx.recv()?;
        if r.ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    println!("\nserved {ok}/{requests} requests in {wall:.3} s");
    println!("  throughput: {:.0} requests/s", requests as f64 / wall);
    println!(
        "  latency: mean {:.1} ms  p50 {:.1} ms  p99 {:.1} ms",
        snap.mean_us / 1e3,
        snap.p50_us / 1e3,
        snap.p99_us / 1e3
    );
    println!(
        "  batching: {} batches, mean size {:.1}; rejected under backpressure: {}",
        snap.batches, snap.mean_batch, snap.rejected
    );
    server.shutdown();
    Ok(())
}

//! Quickstart: compute attention with the exact oracle, the FA-2 baseline
//! and the H-FA hybrid float/log datapath; compare accuracy and the
//! modelled 28 nm hardware cost.
//!
//!     cargo run --release --example quickstart

use hfa::attention::{compute, Impl};
use hfa::config::AcceleratorConfig;
use hfa::hw::cost::compare;
use hfa::proptest::Rng;
use hfa::Mat;

fn main() {
    // a single attention head: 8 queries against 256 keys, d = 64
    let (b, n, d) = (8, 256, 64);
    let mut rng = Rng::new(42);
    let q = Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16();
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();

    let exact = compute(Impl::Exact, &q, &k, &v, None);
    let fa2 = compute(Impl::Fa2, &q, &k, &v, None);
    let hfa = compute(Impl::Hfa, &q, &k, &v, None);

    println!("attention output, first query, first 6 lanes:");
    println!("  exact: {:?}", &exact.row(0)[..6]);
    println!("  FA-2 : {:?}", &fa2.row(0)[..6]);
    println!("  H-FA : {:?}", &hfa.row(0)[..6]);
    println!(
        "\nerror vs exact:  FA-2 max|d| = {:.2e}   H-FA max|d| = {:.3}",
        fa2.max_abs_diff(&exact),
        hfa.max_abs_diff(&exact)
    );
    println!("(H-FA trades bounded Mitchell/PWL/quantization error for hardware savings)");

    // what that buys in silicon (paper Fig. 7 point at d=64)
    let cfg = AcceleratorConfig::default();
    let (fa2_cost, hfa_cost, area_s, power_s) = compare(&cfg, 64);
    println!("\n28 nm accelerator @ 500 MHz, N=1024, 4 KV blocks, d=64:");
    println!(
        "  FA-2: {:.2} mm^2, {:.0} mW    H-FA: {:.2} mm^2, {:.0} mW",
        fa2_cost.total_area_mm2(),
        fa2_cost.total_power_mw(),
        hfa_cost.total_area_mm2(),
        hfa_cost.total_power_mw()
    );
    println!("  H-FA saves {area_s:.1}% area and {power_s:.1}% power (paper: 26.5% / 23.4%)");
}
